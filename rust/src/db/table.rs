//! Typed tables with secondary indexes, history logs, and per-table
//! durability, stored as N-way hash-sharded ordered maps (paper §3.6:
//! hash-based partitioning + bulk operations sustain the production
//! mutation rates; a transactional persistence layer makes restart a
//! routine operation).
//!
//! Layout: every table key is FNV-hashed onto one of `shard_count` shards,
//! each a `RwLock<BTreeMap>`. Single-row operations lock exactly one shard,
//! so writers on different shards never contend; ordered reads (`scan`,
//! `range`-style pages, `for_each`) take all shard read locks at once and
//! k-way-merge the per-shard maps, preserving the global key order of the
//! original single-map implementation. Batched mutations ([`Table::apply`],
//! `insert_bulk` / `upsert_bulk` / `remove_bulk` / `update_bulk`) take all
//! shard write locks once per call — one commit per batch instead of one
//! lock round-trip per row.
//!
//! Durability: a table whose rows implement [`Durable`] can attach a
//! write-ahead log ([`Table::attach_wal`]). Every commit is appended to
//! the log *before* it mutates memory — group-committed, so the bulk
//! path stays one frame (and at most one fsync) per batch.
//! [`Table::checkpoint`] writes a per-shard snapshot fenced by a WAL
//! barrier record and truncates the log; [`Table::recover`] cold-boots
//! the table from snapshot + WAL suffix, rebuilding every registered
//! index through the normal maintenance hooks, and discards a torn
//! final record (detected by checksum) without half-applying it.
//!
//! The table's storage lives behind an `Arc` ([`Table`] is a cheap
//! handle and `Clone`), so the monitoring [`crate::db::Registry`] can
//! hold type-erased persistence handles ([`TablePersist`]) to every
//! catalog table and drive `checkpoint_all` without knowing row types.
//!
//! Paged mode (`[db] memory_budget`): a durable table can bound its
//! resident rows. Each shard tracks a dirty bit (mutated since its
//! snapshot file was written) and an LRU tick; [`Table::enforce_budget`]
//! evicts least-recently-used shards — writing the shard's per-file
//! snapshot first if dirty — until the hot-row count fits the budget.
//! Cold shards serve point reads straight from their file through the
//! captured [`Durable`] decoder; any mutation faults the whole shard
//! back in under its write lock. Ordered scans overlay cold shards from
//! disk without faulting them in. Checkpoints are incremental: only
//! dirty shards are rewritten, and the `{name}.snap` manifest stitches
//! the live snapshot together (see `db::wal`). Secondary indexes stay
//! fully resident across eviction — postings are never dropped — so
//! index-driven lookups keep working against cold shards; the budget
//! bounds row memory, not index memory.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::hash::{Hash, Hasher};
use std::ops::Bound;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};
use crate::db::wal::{
    self, CheckpointStats, CompactStats, Durable, RecoverStats, ReplayOp, SpillStats,
    TablePersist, Wal, WalOptions, WalStats,
};
use crate::db::FnvHasher;
use crate::jsonx::Json;

/// Default shard count for new tables; `Catalog` overrides it from the
/// `[db] shards` config key.
pub const DEFAULT_SHARDS: usize = 8;

/// A row stored in a [`Table`]. The key must be stable for the lifetime of
/// the row (mutating a row's key is a delete + insert).
pub trait Row: Clone + Send + Sync + 'static {
    type Key: Ord + Clone + Hash + Send + Sync + 'static;
    fn key(&self) -> Self::Key;
}

/// Mutation kind recorded in history logs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Insert,
    Update,
    Delete,
}

/// One operation inside a [`Batch`].
pub enum BatchOp<V: Row> {
    /// Insert a new row; the whole batch fails on a duplicate key.
    Insert(V),
    /// Insert or replace.
    Upsert(V),
    /// Remove by key (missing keys are skipped, not errors).
    Remove(V::Key),
}

/// An ordered list of mutations applied in one commit ([`Table::apply`]).
/// Per-key operation order is preserved; atomicity scope is the whole
/// table (all shards locked for the duration of the commit), so readers
/// never observe a half-applied batch.
pub struct Batch<V: Row> {
    ops: Vec<BatchOp<V>>,
}

impl<V: Row> Default for Batch<V> {
    fn default() -> Self {
        Batch { ops: Vec::new() }
    }
}

impl<V: Row> Batch<V> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, row: V) -> &mut Self {
        self.ops.push(BatchOp::Insert(row));
        self
    }

    pub fn upsert(&mut self, row: V) -> &mut Self {
        self.ops.push(BatchOp::Upsert(row));
        self
    }

    pub fn remove(&mut self, key: V::Key) -> &mut Self {
        self.ops.push(BatchOp::Remove(key));
        self
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Outcome of a batch commit.
pub struct BatchSummary<V: Row> {
    pub inserted: usize,
    pub updated: usize,
    /// Rows removed by `Remove` ops, in op order.
    pub removed: Vec<V>,
}

/// One page of an ordered cursor scan ([`Table::scan_page`]).
pub struct Page<V: Row> {
    /// Rows in global key order.
    pub rows: Vec<V>,
    /// Cursor for the next page: `Some(last key)` when more rows remain,
    /// `None` when the scan is exhausted.
    pub next_cursor: Option<V::Key>,
}

/// Maintenance hook a secondary index registers with its table.
trait IndexMaint<V>: Send + Sync {
    fn on_insert(&self, row: &V);
    fn on_remove(&self, row: &V);
}

struct Shard<V: Row> {
    rows: BTreeMap<V::Key, V>,
    /// `Some(n)`: evicted — `rows` is empty and the shard's `n` rows
    /// live in its spill file. Only changes under the shard write lock
    /// (or the all-read-lock checkpoint cut, which never evicts). A cold
    /// shard is always clean: eviction writes the file first, and any
    /// mutation faults the shard back in before touching it.
    cold: Option<usize>,
    /// Mutated since this shard's snapshot file was last written. Set
    /// under the shard write lock; atomically cleared by checkpoint /
    /// eviction at the moment they capture the shard's content, so a
    /// mutation landing after the capture re-dirties the shard.
    dirty: AtomicBool,
    /// Table-wide eviction-clock tick of the most recent access —
    /// the "LRU-ish" ordering [`Table::enforce_budget`] evicts by.
    last_access: AtomicU64,
}

/// The WAL attachment of a durable table: the log handle plus
/// monomorphized codecs captured when the [`Durable`] bound was in
/// scope, so the (bound-free) mutation and read paths can serialize
/// ops and decode spill files.
struct WalBinding<V: Row> {
    wal: Arc<Wal>,
    dir: PathBuf,
    enc_row: fn(&V) -> Json,
    enc_key: fn(&V::Key) -> Json,
    dec_row: fn(&Json) -> Result<V>,
}

/// One to-be-logged mutation, borrowed from the commit in flight.
enum WalOpRef<'a, V: Row> {
    Put(&'a V),
    Del(&'a V::Key),
}

/// The shared storage behind a [`Table`] handle.
struct TableCore<V: Row> {
    name: &'static str,
    shards: Vec<RwLock<Shard<V>>>,
    /// Total live rows, maintained on every mutation: O(1) `len()` with no
    /// locking, and the closure handed to `db::Registry` for monitoring.
    len: Arc<AtomicUsize>,
    /// Mirrors `history.is_some()` so the (majority) history-off case
    /// skips the `history` write lock entirely on every mutation.
    history_on: AtomicBool,
    history: RwLock<Option<Vec<(EpochMs, Op, V)>>>,
    indexes: RwLock<Vec<Arc<dyn IndexMaint<V>>>>,
    wal: RwLock<Option<WalBinding<V>>>,
    contention: Arc<ContentionCounters>,
    /// Hot-row budget for paged mode (0 = paging off). Rows, not bytes:
    /// the RSS proxy the checkpointer's eviction pass bounds.
    budget: AtomicUsize,
    /// Rows currently living only in cold (evicted) shards; `len -
    /// cold_rows` is the hot-row count the budget is checked against.
    cold_rows: AtomicUsize,
    /// Monotonic access clock feeding each shard's `last_access`.
    access_clock: AtomicU64,
    /// Serializes snapshot/spill file IO — checkpoint, eviction, and
    /// WAL compaction hold it across their whole file phase, so a
    /// checkpoint's deferred write of an old cut can never clobber a
    /// newer eviction-written shard file.
    ckpt_io: Mutex<()>,
    // Paged-mode telemetry (see `SpillStats`).
    evictions: AtomicU64,
    fault_ins: AtomicU64,
    disk_reads: AtomicU64,
    /// Test-only: called by `checkpoint` between dropping the shard
    /// guards and starting the file IO, so tests can prove writers make
    /// progress while the snapshot is being written.
    #[cfg(test)]
    ckpt_io_hook: RwLock<Option<Box<dyn Fn() + Send + Sync>>>,
}

/// Lock-acquisition counters for one table, shared with the monitoring
/// registry (`analytics::reports::contention_stats`).
#[derive(Debug, Default)]
pub struct ContentionCounters {
    /// Single-row mutations (each takes exactly one shard write lock).
    pub single_write_locks: AtomicU64,
    /// Batch commits (`apply` / `update_bulk`).
    pub bulk_commits: AtomicU64,
    /// Total shard write locks taken across all batch commits;
    /// `bulk_shards_locked / bulk_commits` is the mean batch footprint.
    pub bulk_shards_locked: AtomicU64,
}

/// A point-in-time read of [`ContentionCounters`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ContentionStats {
    pub shard_count: u64,
    pub single_write_locks: u64,
    pub bulk_commits: u64,
    pub bulk_shards_locked: u64,
}

/// A typed, thread-safe, ordered, hash-sharded table. `Table` is a cheap
/// `Arc` handle: clones share the same storage (what `Catalog` hands the
/// registry as a persistence handle).
pub struct Table<V: Row> {
    core: Arc<TableCore<V>>,
}

impl<V: Row> Clone for Table<V> {
    fn clone(&self) -> Self {
        Table { core: self.core.clone() }
    }
}

fn make_shards<V: Row>(n: usize) -> Vec<RwLock<Shard<V>>> {
    (0..n.max(1))
        .map(|_| {
            RwLock::new(Shard {
                rows: BTreeMap::new(),
                cold: None,
                dirty: AtomicBool::new(false),
                last_access: AtomicU64::new(0),
            })
        })
        .collect()
}

impl<V: Row> Table<V> {
    pub fn new(name: &'static str) -> Self {
        Table {
            core: Arc::new(TableCore {
                name,
                shards: make_shards(DEFAULT_SHARDS),
                len: Arc::new(AtomicUsize::new(0)),
                history_on: AtomicBool::new(false),
                history: RwLock::new(None),
                indexes: RwLock::new(Vec::new()),
                wal: RwLock::new(None),
                contention: Arc::new(ContentionCounters::default()),
                budget: AtomicUsize::new(0),
                cold_rows: AtomicUsize::new(0),
                access_clock: AtomicU64::new(0),
                ckpt_io: Mutex::new(()),
                evictions: AtomicU64::new(0),
                fault_ins: AtomicU64::new(0),
                disk_reads: AtomicU64::new(0),
                #[cfg(test)]
                ckpt_io_hook: RwLock::new(None),
            }),
        }
    }

    /// Rebuild with `n` shards (builder; the table must still be empty
    /// and unshared).
    pub fn with_shards(mut self, n: usize) -> Self {
        assert!(self.is_empty(), "with_shards on non-empty table {}", self.core.name);
        let core = Arc::get_mut(&mut self.core).expect("with_shards on shared table");
        core.shards = make_shards(n);
        self
    }

    /// Enable the history log (paper §3.6 "storing of deleted rows in
    /// historical tables").
    pub fn with_history(self) -> Self {
        *self.core.history.write().unwrap() = Some(Vec::new());
        self.core.history_on.store(true, Ordering::Release);
        self
    }

    /// Record one history entry if history is enabled — the disabled
    /// (default) case is a single relaxed atomic load, not a write-lock
    /// round trip on every mutation.
    fn history_push(&self, now: EpochMs, op: Op, row: &V) {
        if !self.core.history_on.load(Ordering::Acquire) {
            return;
        }
        if let Some(h) = self.core.history.write().unwrap().as_mut() {
            h.push((now, op, row.clone()));
        }
    }

    pub fn name(&self) -> &'static str {
        self.core.name
    }

    pub fn shard_count(&self) -> usize {
        self.core.shards.len()
    }

    fn shard_of(&self, key: &V::Key) -> usize {
        if self.core.shards.len() == 1 {
            return 0;
        }
        let mut h = FnvHasher::default();
        key.hash(&mut h);
        (h.finish() % self.core.shards.len() as u64) as usize
    }

    // ------------------------------------------------------------------
    // paged mode (spill-to-disk shards)
    // ------------------------------------------------------------------

    /// Set the hot-row budget that enables paged mode (0 disables it).
    /// Eviction back under the budget is driven by
    /// [`Table::enforce_budget`] — the checkpointer's job.
    pub fn set_memory_budget(&self, rows: usize) {
        self.core.budget.store(rows, Ordering::Relaxed);
    }

    pub fn memory_budget(&self) -> usize {
        self.core.budget.load(Ordering::Relaxed)
    }

    /// Paged-mode shape: hot/cold split, budget, and spill counters.
    pub fn spill_stats(&self) -> SpillStats {
        let cold_shards = self
            .core
            .shards
            .iter()
            .filter(|s| s.read().unwrap().cold.is_some())
            .count();
        let cold_rows = self.core.cold_rows.load(Ordering::Relaxed);
        SpillStats {
            shard_count: self.core.shards.len(),
            cold_shards,
            hot_rows: self.len().saturating_sub(cold_rows),
            cold_rows,
            budget: self.core.budget.load(Ordering::Relaxed),
            evictions: self.core.evictions.load(Ordering::Relaxed),
            fault_ins: self.core.fault_ins.load(Ordering::Relaxed),
            disk_reads: self.core.disk_reads.load(Ordering::Relaxed),
        }
    }

    /// Bump the shard's LRU tick (any access, read or write).
    fn touch(&self, shard: &Shard<V>) {
        let t = self.core.access_clock.fetch_add(1, Ordering::Relaxed) + 1;
        shard.last_access.store(t, Ordering::Relaxed);
    }

    /// Decode shard `i`'s spill/snapshot file through the captured
    /// [`Durable`] codec. Missing file (or no WAL binding) reads as
    /// empty; IO/decode errors are logged and read as empty too — shard
    /// files are written atomically under the IO mutex, so a bad file
    /// is corruption, not a race.
    fn read_cold_shard(&self, i: usize) -> BTreeMap<V::Key, V> {
        let guard = self.core.wal.read().unwrap();
        let Some(b) = guard.as_ref() else {
            return BTreeMap::new();
        };
        let path = wal::shard_snapshot_file(&b.dir, self.core.name, i);
        let frames = match wal::read_frames(&path) {
            Ok(f) => f,
            Err(e) => {
                crate::log_warn!(
                    "table {}: reading spill file for shard {i} failed: {e}",
                    self.core.name
                );
                return BTreeMap::new();
            }
        };
        let mut out = BTreeMap::new();
        for f in &frames {
            if f.opt_str("k") != Some("shard") {
                continue;
            }
            let Some(rows) = f.get("rows").and_then(Json::as_arr) else { continue };
            for rj in rows {
                match (b.dec_row)(rj) {
                    Ok(row) => {
                        out.insert(row.key(), row);
                    }
                    Err(e) => crate::log_warn!(
                        "table {}: decoding a spilled row of shard {i} failed: {e}",
                        self.core.name
                    ),
                }
            }
        }
        out
    }

    /// Fault an evicted shard's rows back into memory. The caller holds
    /// the shard's *write* lock and `i` is that shard's index. Indexes
    /// kept their postings across eviction, so nothing is re-indexed.
    fn fault_in(&self, i: usize, shard: &mut Shard<V>) {
        let Some(n) = shard.cold.take() else { return };
        let rows = self.read_cold_shard(i);
        if rows.len() != n {
            crate::log_warn!(
                "table {}: shard {i} faulted in {} rows, expected {n}",
                self.core.name,
                rows.len()
            );
        }
        self.core.cold_rows.fetch_sub(n, Ordering::Relaxed);
        self.core.fault_ins.fetch_add(1, Ordering::Relaxed);
        shard.rows = rows;
    }

    /// Attach a secondary index. Existing rows are back-filled, so indexes
    /// can be added to live, non-empty tables; mutation is blocked for the
    /// duration of the back-fill so no row is missed or double-counted.
    pub fn add_index<IK>(&self, index: &Index<V, IK>) -> Result<()>
    where
        IK: Ord + Clone + Send + Sync + 'static,
    {
        self.attach_maint(index.maint.clone())
    }

    /// Attach a multi-key (inverted) index — same back-fill and liveness
    /// guarantees as [`Table::add_index`].
    pub fn add_multi_index<IK>(&self, index: &MultiIndex<V, IK>) -> Result<()>
    where
        IK: Ord + Clone + Send + Sync + 'static,
    {
        self.attach_maint(index.maint.clone())
    }

    fn attach_maint(&self, maint: Arc<dyn IndexMaint<V>>) -> Result<()> {
        // Read locks suffice to fence the back-fill: every mutator takes
        // its shard *write* lock before consulting `indexes`, so while
        // all read locks are held no row can be added or removed. Cold
        // shards back-fill from their spill files without faulting in.
        let guards: Vec<_> = self.core.shards.iter().map(|s| s.read().unwrap()).collect();
        let mut indexes = self.core.indexes.write().unwrap();
        for (i, g) in guards.iter().enumerate() {
            if g.cold.is_some() {
                for row in self.read_cold_shard(i).values() {
                    maint.on_insert(row);
                }
            } else {
                for row in g.rows.values() {
                    maint.on_insert(row);
                }
            }
        }
        indexes.push(maint);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.core.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// O(1) live-row counter, detached from the table's lifetime — what
    /// [`crate::db::Registry`] stores for monitoring probes.
    pub fn len_counter(&self) -> Arc<dyn Fn() -> usize + Send + Sync> {
        let len = self.core.len.clone();
        Arc::new(move || len.load(Ordering::Relaxed))
    }

    /// Point-in-time shard-lock contention counters.
    pub fn contention_stats(&self) -> ContentionStats {
        let c = &self.core.contention;
        ContentionStats {
            shard_count: self.core.shards.len() as u64,
            single_write_locks: c.single_write_locks.load(Ordering::Relaxed),
            bulk_commits: c.bulk_commits.load(Ordering::Relaxed),
            bulk_shards_locked: c.bulk_shards_locked.load(Ordering::Relaxed),
        }
    }

    /// Detached contention probe, the lock-traffic analogue of
    /// [`Table::len_counter`] for [`crate::db::Registry`].
    pub fn contention_probe(&self) -> Arc<dyn Fn() -> ContentionStats + Send + Sync> {
        let counters = self.core.contention.clone();
        let shard_count = self.core.shards.len() as u64;
        Arc::new(move || ContentionStats {
            shard_count,
            single_write_locks: counters.single_write_locks.load(Ordering::Relaxed),
            bulk_commits: counters.bulk_commits.load(Ordering::Relaxed),
            bulk_shards_locked: counters.bulk_shards_locked.load(Ordering::Relaxed),
        })
    }

    /// Append the ops of one commit to the WAL, if attached. Called with
    /// the relevant shard locks held, *before* the in-memory mutation
    /// (classic WAL ordering), so log order matches commit order per key.
    /// IO errors are logged, not propagated: the in-memory table stays
    /// authoritative for the running process.
    fn wal_log(&self, ops: &[WalOpRef<'_, V>]) {
        let guard = self.core.wal.read().unwrap();
        let Some(binding) = guard.as_ref() else { return };
        let jops: Vec<Json> = ops
            .iter()
            .map(|op| match op {
                WalOpRef::Put(v) => Json::obj().with("o", "u").with("row", (binding.enc_row)(v)),
                WalOpRef::Del(k) => Json::obj().with("o", "r").with("key", (binding.enc_key)(k)),
            })
            .collect();
        if let Err(e) = binding.wal.commit(jops) {
            crate::log_warn!("table {}: WAL append failed: {e}", self.core.name);
        }
    }

    /// Insert a new row; errors on duplicate key.
    pub fn insert(&self, row: V, now: EpochMs) -> Result<()> {
        let key = row.key();
        let si = self.shard_of(&key);
        let mut shard = self.core.shards[si].write().unwrap();
        self.core.contention.single_write_locks.fetch_add(1, Ordering::Relaxed);
        self.touch(&shard);
        self.fault_in(si, &mut shard);
        if shard.rows.contains_key(&key) {
            return Err(RucioError::Duplicate(format!(
                "table {}: duplicate key",
                self.core.name
            )));
        }
        self.wal_log(&[WalOpRef::Put(&row)]);
        for idx in self.core.indexes.read().unwrap().iter() {
            idx.on_insert(&row);
        }
        self.history_push(now, Op::Insert, &row);
        shard.rows.insert(key, row);
        shard.dirty.store(true, Ordering::Release);
        self.core.len.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Insert or replace.
    pub fn upsert(&self, row: V, now: EpochMs) {
        let key = row.key();
        let si = self.shard_of(&key);
        let mut shard = self.core.shards[si].write().unwrap();
        self.core.contention.single_write_locks.fetch_add(1, Ordering::Relaxed);
        self.touch(&shard);
        self.fault_in(si, &mut shard);
        self.wal_log(&[WalOpRef::Put(&row)]);
        let indexes = self.core.indexes.read().unwrap();
        if let Some(old) = shard.rows.get(&key) {
            for idx in indexes.iter() {
                idx.on_remove(old);
            }
        } else {
            self.core.len.fetch_add(1, Ordering::Relaxed);
        }
        for idx in indexes.iter() {
            idx.on_insert(&row);
        }
        self.history_push(now, Op::Update, &row);
        shard.rows.insert(key, row);
        shard.dirty.store(true, Ordering::Release);
    }

    pub fn get(&self, key: &V::Key) -> Option<V> {
        let si = self.shard_of(key);
        let shard = self.core.shards[si].read().unwrap();
        self.touch(&shard);
        if shard.cold.is_some() {
            // Served from the spill file without faulting the shard in
            // (cold ⇒ clean ⇒ the file is the shard's exact content).
            self.core.disk_reads.fetch_add(1, Ordering::Relaxed);
            return self.read_cold_shard(si).remove(key);
        }
        shard.rows.get(key).cloned()
    }

    /// Project a row under the shard read lock without cloning the whole
    /// row — the cheap read path when only one field is needed (e.g.
    /// returning a DID's metadata map without copying every column).
    pub fn read<R, F: FnOnce(&V) -> R>(&self, key: &V::Key, f: F) -> Option<R> {
        let si = self.shard_of(key);
        let shard = self.core.shards[si].read().unwrap();
        self.touch(&shard);
        if shard.cold.is_some() {
            self.core.disk_reads.fetch_add(1, Ordering::Relaxed);
            return self.read_cold_shard(si).get(key).map(f);
        }
        shard.rows.get(key).map(f)
    }

    pub fn contains(&self, key: &V::Key) -> bool {
        let si = self.shard_of(key);
        let shard = self.core.shards[si].read().unwrap();
        self.touch(&shard);
        if shard.cold.is_some() {
            self.core.disk_reads.fetch_add(1, Ordering::Relaxed);
            return self.read_cold_shard(si).contains_key(key);
        }
        shard.rows.contains_key(key)
    }

    /// In-place mutation through a closure; index entries are refreshed.
    /// Returns the updated row, or `None` if absent.
    pub fn update<F: FnOnce(&mut V)>(&self, key: &V::Key, now: EpochMs, f: F) -> Option<V> {
        let si = self.shard_of(key);
        let mut shard = self.core.shards[si].write().unwrap();
        self.core.contention.single_write_locks.fetch_add(1, Ordering::Relaxed);
        self.touch(&shard);
        self.fault_in(si, &mut shard);
        let row = shard.rows.get(key)?.clone();
        let indexes = self.core.indexes.read().unwrap();
        for idx in indexes.iter() {
            idx.on_remove(&row);
        }
        let mut new_row = row;
        f(&mut new_row);
        debug_assert!(new_row.key() == *key, "update must not change the primary key");
        self.wal_log(&[WalOpRef::Put(&new_row)]);
        for idx in indexes.iter() {
            idx.on_insert(&new_row);
        }
        self.history_push(now, Op::Update, &new_row);
        shard.rows.insert(key.clone(), new_row.clone());
        shard.dirty.store(true, Ordering::Release);
        Some(new_row)
    }

    pub fn remove(&self, key: &V::Key, now: EpochMs) -> Option<V> {
        let si = self.shard_of(key);
        let mut shard = self.core.shards[si].write().unwrap();
        self.core.contention.single_write_locks.fetch_add(1, Ordering::Relaxed);
        self.touch(&shard);
        self.fault_in(si, &mut shard);
        if !shard.rows.contains_key(key) {
            return None;
        }
        self.wal_log(&[WalOpRef::Del(key)]);
        let row = shard.rows.remove(key)?;
        shard.dirty.store(true, Ordering::Release);
        self.core.len.fetch_sub(1, Ordering::Relaxed);
        for idx in self.core.indexes.read().unwrap().iter() {
            idx.on_remove(&row);
        }
        self.history_push(now, Op::Delete, &row);
        Some(row)
    }

    // ------------------------------------------------------------------
    // batch mutation (one commit, touched shards locked once)
    // ------------------------------------------------------------------

    /// Write-lock exactly the shards in `touched`, in ascending shard
    /// index — the same order `checkpoint`'s all-shard cut and the
    /// merged scans use, so bulk commits can never deadlock against
    /// them. Returns the guards plus a shard-index → guard-position
    /// map for `guards[slot[shard_of(key)]]` addressing.
    #[allow(clippy::type_complexity)]
    fn lock_touched(
        &self,
        touched: &BTreeSet<usize>,
    ) -> (Vec<std::sync::RwLockWriteGuard<'_, Shard<V>>>, Vec<usize>) {
        let mut slot = vec![usize::MAX; self.core.shards.len()];
        let mut guards = Vec::with_capacity(touched.len());
        for (pos, si) in touched.iter().enumerate() {
            slot[*si] = pos;
            let mut g = self.core.shards[*si].write().unwrap();
            self.touch(&g);
            self.fault_in(*si, &mut g);
            // Conservatively dirty every touched shard: a batch that
            // ends up not mutating one (e.g. removes of missing keys)
            // just costs that shard one spurious rewrite next
            // checkpoint — never a missed one.
            g.dirty.store(true, Ordering::Release);
            guards.push(g);
        }
        self.core.contention.bulk_commits.fetch_add(1, Ordering::Relaxed);
        self.core
            .contention
            .bulk_shards_locked
            .fetch_add(touched.len() as u64, Ordering::Relaxed);
        (guards, slot)
    }

    /// Apply a batch atomically: the write locks of every *touched*
    /// shard are held together for the whole commit, so concurrent
    /// readers (which take shard locks in the same ascending order) see
    /// either none or all of the batch — untouched shards stay free for
    /// other writers. `Insert` duplicates (against the table or an
    /// earlier op in the same batch) fail the entire batch before any
    /// mutation. The closure-free op set keeps batches send-able across
    /// layers. With a WAL attached, the whole batch is one
    /// group-committed log frame — recovery can never observe half of it.
    ///
    /// Do not touch the same table from index hooks or in between — the
    /// commit holds every touched shard lock.
    pub fn apply(&self, batch: Batch<V>, now: EpochMs) -> Result<BatchSummary<V>> {
        let mut touched: BTreeSet<usize> = BTreeSet::new();
        for op in &batch.ops {
            touched.insert(match op {
                BatchOp::Insert(row) | BatchOp::Upsert(row) => self.shard_of(&row.key()),
                BatchOp::Remove(k) => self.shard_of(k),
            });
        }
        let (mut guards, slot) = self.lock_touched(&touched);
        // Dry-run: validate Insert ops against an overlay of the batch.
        let mut overlay: BTreeMap<V::Key, bool> = BTreeMap::new();
        for op in &batch.ops {
            match op {
                BatchOp::Insert(row) => {
                    let k = row.key();
                    let exists = match overlay.get(&k) {
                        Some(e) => *e,
                        None => guards[slot[self.shard_of(&k)]].rows.contains_key(&k),
                    };
                    if exists {
                        return Err(RucioError::Duplicate(format!(
                            "table {}: duplicate key in batch",
                            self.core.name
                        )));
                    }
                    overlay.insert(k, true);
                }
                BatchOp::Upsert(row) => {
                    overlay.insert(row.key(), true);
                }
                BatchOp::Remove(k) => {
                    overlay.insert(k.clone(), false);
                }
            }
        }
        // Log first (one frame for the whole batch), then commit.
        {
            let refs: Vec<WalOpRef<'_, V>> = batch
                .ops
                .iter()
                .map(|op| match op {
                    BatchOp::Insert(row) | BatchOp::Upsert(row) => WalOpRef::Put(row),
                    BatchOp::Remove(k) => WalOpRef::Del(k),
                })
                .collect();
            self.wal_log(&refs);
        }
        let indexes = self.core.indexes.read().unwrap();
        let mut history = if self.core.history_on.load(Ordering::Acquire) {
            Some(self.core.history.write().unwrap())
        } else {
            None
        };
        let mut summary = BatchSummary { inserted: 0, updated: 0, removed: Vec::new() };
        for op in batch.ops {
            match op {
                BatchOp::Insert(row) => {
                    let k = row.key();
                    let si = slot[self.shard_of(&k)];
                    for idx in indexes.iter() {
                        idx.on_insert(&row);
                    }
                    if let Some(h) = history.as_mut().and_then(|g| g.as_mut()) {
                        h.push((now, Op::Insert, row.clone()));
                    }
                    guards[si].rows.insert(k, row);
                    self.core.len.fetch_add(1, Ordering::Relaxed);
                    summary.inserted += 1;
                }
                BatchOp::Upsert(row) => {
                    let k = row.key();
                    let si = slot[self.shard_of(&k)];
                    if let Some(old) = guards[si].rows.get(&k) {
                        for idx in indexes.iter() {
                            idx.on_remove(old);
                        }
                        summary.updated += 1;
                    } else {
                        self.core.len.fetch_add(1, Ordering::Relaxed);
                        summary.inserted += 1;
                    }
                    for idx in indexes.iter() {
                        idx.on_insert(&row);
                    }
                    if let Some(h) = history.as_mut().and_then(|g| g.as_mut()) {
                        h.push((now, Op::Update, row.clone()));
                    }
                    guards[si].rows.insert(k, row);
                }
                BatchOp::Remove(k) => {
                    let si = slot[self.shard_of(&k)];
                    if let Some(old) = guards[si].rows.remove(&k) {
                        self.core.len.fetch_sub(1, Ordering::Relaxed);
                        for idx in indexes.iter() {
                            idx.on_remove(&old);
                        }
                        if let Some(h) = history.as_mut().and_then(|g| g.as_mut()) {
                            h.push((now, Op::Delete, old.clone()));
                        }
                        summary.removed.push(old);
                    }
                }
            }
        }
        Ok(summary)
    }

    /// Insert many rows in one commit; the whole call fails (with no
    /// partial state) on any duplicate key.
    pub fn insert_bulk(&self, rows: Vec<V>, now: EpochMs) -> Result<usize> {
        if rows.is_empty() {
            return Ok(0);
        }
        let mut batch = Batch::new();
        for row in rows {
            batch.insert(row);
        }
        Ok(self.apply(batch, now)?.inserted)
    }

    /// Insert-or-replace many rows in one commit.
    pub fn upsert_bulk(&self, rows: Vec<V>, now: EpochMs) -> usize {
        if rows.is_empty() {
            return 0;
        }
        let mut batch = Batch::new();
        for row in rows {
            batch.upsert(row);
        }
        let s = self.apply(batch, now).expect("upsert batch cannot fail");
        s.inserted + s.updated
    }

    /// Remove many keys in one commit; missing keys are skipped. Returns
    /// the removed rows in op order.
    pub fn remove_bulk(&self, keys: &[V::Key], now: EpochMs) -> Vec<V> {
        if keys.is_empty() {
            return Vec::new();
        }
        let mut batch = Batch::new();
        for k in keys {
            batch.remove(k.clone());
        }
        self.apply(batch, now).expect("remove batch cannot fail").removed
    }

    /// Apply one closure to many rows in a single commit (bulk state
    /// transitions). Missing keys are skipped; index entries and history
    /// are maintained per row. Returns the updated rows in key-arg order.
    pub fn update_bulk<F: FnMut(&mut V)>(
        &self,
        keys: &[V::Key],
        now: EpochMs,
        mut f: F,
    ) -> Vec<V> {
        if keys.is_empty() {
            return Vec::new();
        }
        let touched: BTreeSet<usize> = keys.iter().map(|k| self.shard_of(k)).collect();
        let (mut guards, slot) = self.lock_touched(&touched);
        let indexes = self.core.indexes.read().unwrap();
        let mut history = if self.core.history_on.load(Ordering::Acquire) {
            Some(self.core.history.write().unwrap())
        } else {
            None
        };
        let mut out = Vec::with_capacity(keys.len());
        for key in keys {
            let si = slot[self.shard_of(key)];
            let Some(row) = guards[si].rows.get(key) else { continue };
            let row = row.clone();
            for idx in indexes.iter() {
                idx.on_remove(&row);
            }
            let mut new_row = row;
            f(&mut new_row);
            debug_assert!(new_row.key() == *key, "update must not change the primary key");
            for idx in indexes.iter() {
                idx.on_insert(&new_row);
            }
            if let Some(h) = history.as_mut().and_then(|g| g.as_mut()) {
                h.push((now, Op::Update, new_row.clone()));
            }
            guards[si].rows.insert(key.clone(), new_row.clone());
            out.push(new_row);
        }
        // One log frame for the whole bulk transition (still under the
        // shard locks, so readers and the log agree on the commit point).
        let refs: Vec<WalOpRef<'_, V>> = out.iter().map(WalOpRef::Put).collect();
        self.wal_log(&refs);
        out
    }

    // ------------------------------------------------------------------
    // recovery load path (no WAL echo, no history)
    // ------------------------------------------------------------------

    /// Insert-or-replace during recovery: maintains indexes and the row
    /// counter but writes neither history nor WAL (the row came *from*
    /// the log). `mark_dirty = false` is the snapshot-load fast path
    /// when the shard layout matches the manifest — the row is landing
    /// exactly where its shard file already has it, so the shard stays
    /// clean and incremental checkpoints survive the restart.
    fn load_row(&self, row: V, mark_dirty: bool) {
        let key = row.key();
        let mut shard = self.core.shards[self.shard_of(&key)].write().unwrap();
        let indexes = self.core.indexes.read().unwrap();
        if let Some(old) = shard.rows.get(&key) {
            for idx in indexes.iter() {
                idx.on_remove(old);
            }
        } else {
            self.core.len.fetch_add(1, Ordering::Relaxed);
        }
        for idx in indexes.iter() {
            idx.on_insert(&row);
        }
        shard.rows.insert(key, row);
        if mark_dirty {
            shard.dirty.store(true, Ordering::Release);
        }
    }

    /// Remove during recovery (missing keys are no-ops).
    fn unload_row(&self, key: &V::Key) {
        let mut shard = self.core.shards[self.shard_of(key)].write().unwrap();
        if let Some(old) = shard.rows.remove(key) {
            shard.dirty.store(true, Ordering::Release);
            self.core.len.fetch_sub(1, Ordering::Relaxed);
            for idx in self.core.indexes.read().unwrap().iter() {
                idx.on_remove(&old);
            }
        }
    }

    // ------------------------------------------------------------------
    // ordered reads (k-way merge across shards)
    // ------------------------------------------------------------------

    /// Load every cold shard's spill content into owned maps (indexed by
    /// shard) so ordered scans can merge hot and cold shards uniformly
    /// without faulting anything in. Caller holds all shard read locks.
    fn cold_overlay(
        &self,
        guards: &[std::sync::RwLockReadGuard<'_, Shard<V>>],
    ) -> Vec<Option<BTreeMap<V::Key, V>>> {
        guards
            .iter()
            .enumerate()
            .map(|(i, g)| g.cold.map(|_| self.read_cold_shard(i)))
            .collect()
    }

    /// Visit every row in global key order until `f` returns false.
    /// Takes all shard read locks at once (consistent snapshot) and merges
    /// the per-shard ordered maps; cold shards merge from their spill
    /// files.
    fn merged_for_each<F: FnMut(&V) -> bool>(&self, mut f: F) {
        let guards: Vec<_> = self.core.shards.iter().map(|s| s.read().unwrap()).collect();
        let cold = self.cold_overlay(&guards);
        let mut iters: Vec<_> = guards
            .iter()
            .zip(cold.iter())
            .map(|(g, c)| c.as_ref().unwrap_or(&g.rows).iter())
            .collect();
        let mut heap: BinaryHeap<Reverse<(&V::Key, usize)>> = BinaryHeap::new();
        let mut heads: Vec<Option<&V>> = vec![None; iters.len()];
        for (i, it) in iters.iter_mut().enumerate() {
            if let Some((k, v)) = it.next() {
                heap.push(Reverse((k, i)));
                heads[i] = Some(v);
            }
        }
        while let Some(Reverse((_k, i))) = heap.pop() {
            let v = heads[i].take().expect("head follows heap");
            if !f(v) {
                return;
            }
            if let Some((k2, v2)) = iters[i].next() {
                heap.push(Reverse((k2, i)));
                heads[i] = Some(v2);
            }
        }
    }

    /// Snapshot scan with a filter (clones matching rows), in key order.
    pub fn scan<F: FnMut(&V) -> bool>(&self, mut pred: F) -> Vec<V> {
        let mut out = Vec::new();
        self.merged_for_each(|v| {
            if pred(v) {
                out.push(v.clone());
            }
            true
        });
        out
    }

    /// Scan at most `limit` matching rows (the daemon "read a batch" path —
    /// keeps reaper/conveyor scans O(batch) when combined with indexes).
    pub fn scan_limit<F: FnMut(&V) -> bool>(&self, limit: usize, mut pred: F) -> Vec<V> {
        let mut out = Vec::new();
        self.merged_for_each(|v| {
            if pred(v) {
                out.push(v.clone());
            }
            out.len() < limit
        });
        out
    }

    /// Cursor-based pagination in key order: rows strictly after `cursor`
    /// (all rows when `None`), up to `limit`. The returned
    /// [`Page::next_cursor`] feeds the next call; `None` means exhausted.
    pub fn scan_page(&self, cursor: Option<&V::Key>, limit: usize) -> Page<V> {
        match cursor {
            Some(c) => self.range_page(Bound::Excluded(c), Bound::Unbounded, limit),
            None => self.range_page(Bound::Unbounded, Bound::Unbounded, limit),
        }
    }

    /// One page of rows with keys in `(lo, hi)` bounds, in key order.
    pub fn range_page(&self, lo: Bound<&V::Key>, hi: Bound<&V::Key>, limit: usize) -> Page<V> {
        let limit = limit.max(1);
        let guards: Vec<_> = self.core.shards.iter().map(|s| s.read().unwrap()).collect();
        let cold = self.cold_overlay(&guards);
        let mut iters: Vec<_> = guards
            .iter()
            .zip(cold.iter())
            .map(|(g, c)| c.as_ref().unwrap_or(&g.rows).range((lo, hi)))
            .collect();
        let mut heap: BinaryHeap<Reverse<(&V::Key, usize)>> = BinaryHeap::new();
        let mut heads: Vec<Option<&V>> = vec![None; iters.len()];
        for (i, it) in iters.iter_mut().enumerate() {
            if let Some((k, v)) = it.next() {
                heap.push(Reverse((k, i)));
                heads[i] = Some(v);
            }
        }
        let mut rows: Vec<V> = Vec::new();
        let mut next_cursor = None;
        while let Some(Reverse((_k, i))) = heap.pop() {
            if rows.len() >= limit {
                next_cursor = rows.last().map(|r| r.key());
                break;
            }
            let v = heads[i].take().expect("head follows heap");
            rows.push(v.clone());
            if let Some((k2, v2)) = iters[i].next() {
                heap.push(Reverse((k2, i)));
                heads[i] = Some(v2);
            }
        }
        Page { rows, next_cursor }
    }

    /// Fold over all rows without cloning, in key order.
    pub fn fold<A, F: FnMut(A, &V) -> A>(&self, init: A, mut f: F) -> A {
        let mut acc = Some(init);
        self.merged_for_each(|v| {
            acc = Some(f(acc.take().expect("acc always present"), v));
            true
        });
        acc.expect("acc always present")
    }

    /// Visit every row (no clone), in key order; used by reports.
    pub fn for_each<F: FnMut(&V)>(&self, mut f: F) {
        self.merged_for_each(|v| {
            f(v);
            true
        });
    }

    /// Project matching rows without cloning whole rows (read-heavy report
    /// paths: extract only the cells you need).
    pub fn filter_map<T, F: FnMut(&V) -> Option<T>>(&self, mut f: F) -> Vec<T> {
        let mut out = Vec::new();
        self.merged_for_each(|v| {
            if let Some(t) = f(v) {
                out.push(t);
            }
            true
        });
        out
    }

    /// Count matching rows without cloning.
    pub fn count_where<F: FnMut(&V) -> bool>(&self, mut pred: F) -> usize {
        let mut n = 0;
        self.merged_for_each(|v| {
            if pred(v) {
                n += 1;
            }
            true
        });
        n
    }

    /// All keys in order (cheap-ish snapshot for iteration patterns).
    pub fn keys(&self) -> Vec<V::Key> {
        let mut out = Vec::with_capacity(self.len());
        self.merged_for_each(|v| {
            out.push(v.key());
            true
        });
        out
    }

    /// History snapshot (empty if history is disabled). History is
    /// in-memory only — a recovered table starts with an empty log.
    pub fn history(&self) -> Vec<(EpochMs, Op, V)> {
        self.core.history.read().unwrap().clone().unwrap_or_default()
    }
}

// ---------------------------------------------------------------------
// durability (WAL + snapshots) — rows must be `Durable`
// ---------------------------------------------------------------------

impl<V: Durable> Table<V> {
    /// Attach (or re-attach) a write-ahead log under `dir`. An existing
    /// log is continued (its seq counter resumes past the valid prefix;
    /// a torn tail is truncated). From this point on, every mutation is
    /// logged before it is applied.
    pub fn attach_wal(&self, dir: &Path, opts: WalOptions) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let wal = Arc::new(Wal::open(&wal::wal_file(dir, self.core.name), opts)?);
        *self.core.wal.write().unwrap() = Some(WalBinding {
            wal,
            dir: dir.to_path_buf(),
            enc_row: V::row_to_json,
            enc_key: V::key_to_json,
            dec_row: V::row_from_json,
        });
        Ok(())
    }

    /// Clone the WAL handle + dir out of the binding, or error: every
    /// checkpoint-path operation needs both.
    fn wal_binding(&self, what: &str) -> Result<(Arc<Wal>, PathBuf)> {
        let guard = self.core.wal.read().unwrap();
        let binding = guard.as_ref().ok_or_else(|| {
            RucioError::DatabaseError(format!(
                "table {}: {what} requires an attached WAL",
                self.core.name
            ))
        })?;
        Ok((binding.wal.clone(), binding.dir.clone()))
    }

    /// Write an incremental snapshot fenced by a WAL barrier, then
    /// truncate the log to the barrier (plus any later records). Only
    /// *dirty* shards are serialized and rewritten; clean and cold
    /// shards keep their existing files, and the `{name}.snap` manifest
    /// stitches the cut together. The shard read locks are held only
    /// across the barrier and the in-memory serialization — the file IO
    /// happens after they drop, so writers are never stalled behind the
    /// disk. A mutation landing between the cut and the file write
    /// re-dirties its shard (the write captures pre-mutation content,
    /// which the preserved WAL suffix replays over). Requires an
    /// attached WAL.
    pub fn checkpoint(&self) -> Result<CheckpointStats> {
        let (wal_handle, dir) = self.wal_binding("checkpoint")?;
        // Serialize the file phase against eviction and compaction: an
        // eviction-written shard file is newer than this cut and must
        // not be clobbered by our deferred write of older content.
        let _io = self.core.ckpt_io.lock().unwrap();
        let guards: Vec<_> = self.core.shards.iter().map(|s| s.read().unwrap()).collect();
        let seq = wal_handle.barrier()?;
        let fsync = wal_handle.fsync_enabled();
        let mut shard_rows = Vec::with_capacity(guards.len());
        let mut rows_total = 0usize;
        let mut to_write: Vec<(usize, Json)> = Vec::new();
        for (i, g) in guards.iter().enumerate() {
            let n = g.cold.unwrap_or_else(|| g.rows.len());
            shard_rows.push(n);
            rows_total += n;
            if g.cold.is_some() {
                continue; // cold ⇒ clean ⇒ the spill file is current
            }
            let dirty = g.dirty.swap(false, Ordering::AcqRel);
            let have_file =
                || wal::shard_snapshot_file(&dir, self.core.name, i).exists();
            if dirty || (n > 0 && !have_file()) {
                let rows: Vec<Json> = g.rows.values().map(|r| r.row_to_json()).collect();
                to_write.push((
                    i,
                    Json::obj().with("k", "shard").with("i", i).with("rows", Json::Arr(rows)),
                ));
            }
        }
        drop(guards);
        #[cfg(test)]
        if let Some(hook) = self.core.ckpt_io_hook.read().unwrap().as_ref() {
            hook();
        }
        let mut snapshot_bytes = 0u64;
        for (i, frame) in &to_write {
            let path = wal::shard_snapshot_file(&dir, self.core.name, *i);
            if let Err(e) = wal::write_frames_atomic(&path, std::slice::from_ref(frame), fsync) {
                // Put the dirty bits back so the next sweep retries
                // every shard of this cut (re-marking already-written
                // ones only costs a spurious rewrite). The WAL is not
                // truncated, so nothing is lost.
                for (j, _) in &to_write {
                    self.core.shards[*j].read().unwrap().dirty.store(true, Ordering::Release);
                }
                return Err(e);
            }
            snapshot_bytes += std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        }
        // The manifest is written after the shard files: a crash in
        // between leaves the old manifest pointing at shard files that
        // are at least as new as its fence — idempotent replay of the
        // old WAL suffix recovers exactly.
        let mut frames = Vec::with_capacity(shard_rows.len() + 1);
        frames.push(
            Json::obj()
                .with("k", "snap")
                .with("table", self.core.name)
                .with("ckpt", seq)
                .with("shards", shard_rows.len()),
        );
        for (i, n) in shard_rows.iter().enumerate() {
            frames.push(Json::obj().with("k", "shardref").with("i", i).with("rows", *n));
        }
        let snap = wal::snapshot_file(&dir, self.core.name);
        snapshot_bytes += wal::write_frames_atomic(&snap, &frames, fsync)?;
        wal::remove_orphan_shard_files(&dir, self.core.name, shard_rows.len());
        wal_handle.truncate_to_barrier(seq)?;
        Ok(CheckpointStats {
            rows: rows_total,
            snapshot_bytes,
            seq,
            shards_written: to_write.len(),
            shards_skipped: shard_rows.len() - to_write.len(),
        })
    }

    /// Would a checkpoint change what's on disk? True when the WAL has
    /// records past the last barrier or any shard is dirty (e.g. a
    /// failed checkpoint restored dirty bits after the barrier moved).
    pub fn needs_checkpoint(&self) -> bool {
        let Some(stats) = self.wal_stats() else { return false };
        if stats.records_since_checkpoint > 0 {
            return true;
        }
        self.core
            .shards
            .iter()
            .any(|s| s.read().unwrap().dirty.load(Ordering::Acquire))
    }

    /// Evict least-recently-used shards until the hot-row count fits the
    /// budget ([`Table::set_memory_budget`]; no-op at 0 or with no WAL
    /// attached). A dirty shard (or one with no file yet) gets its spill
    /// file written before its rows are dropped, so cold shards can
    /// always be served from disk and recovery stays exact whether or
    /// not a checkpoint intervened — shard files written here are newer
    /// than the manifest's fence, which idempotent full-row replay
    /// tolerates. Returns the number of shards evicted.
    pub fn enforce_budget(&self) -> Result<usize> {
        let budget = self.core.budget.load(Ordering::Relaxed);
        if budget == 0 {
            return Ok(0);
        }
        let hot = self.len().saturating_sub(self.core.cold_rows.load(Ordering::Relaxed));
        if hot <= budget {
            return Ok(0);
        }
        let Ok((wal_handle, dir)) = self.wal_binding("enforce_budget") else {
            return Ok(0); // paging without a WAL: nowhere to spill
        };
        let fsync = wal_handle.fsync_enabled();
        let _io = self.core.ckpt_io.lock().unwrap();
        // Coldest-first over the currently-hot shards. The ticks are a
        // racy snapshot — LRU-ish is all eviction needs.
        let mut order: Vec<(u64, usize)> = Vec::new();
        for (i, s) in self.core.shards.iter().enumerate() {
            let g = s.read().unwrap();
            if g.cold.is_none() && !g.rows.is_empty() {
                order.push((g.last_access.load(Ordering::Relaxed), i));
            }
        }
        order.sort_unstable();
        let mut hot = hot;
        let mut evicted = 0usize;
        for (_, i) in order {
            if hot <= budget {
                break;
            }
            let mut g = self.core.shards[i].write().unwrap();
            if g.cold.is_some() || g.rows.is_empty() {
                continue; // changed while we were sorting
            }
            let n = g.rows.len();
            let path = wal::shard_snapshot_file(&dir, self.core.name, i);
            if g.dirty.load(Ordering::Acquire) || !path.exists() {
                let rows: Vec<Json> = g.rows.values().map(|r| r.row_to_json()).collect();
                let frame =
                    Json::obj().with("k", "shard").with("i", i).with("rows", Json::Arr(rows));
                wal::write_frames_atomic(&path, std::slice::from_ref(&frame), fsync)?;
                g.dirty.store(false, Ordering::Release);
            }
            g.rows = BTreeMap::new();
            g.cold = Some(n);
            self.core.cold_rows.fetch_add(n, Ordering::Relaxed);
            self.core.evictions.fetch_add(1, Ordering::Relaxed);
            hot -= n.min(hot);
            evicted += 1;
        }
        Ok(evicted)
    }

    /// Fold the WAL down to at most one barrier plus one commit frame:
    /// records at or before the on-disk manifest's fence are dropped
    /// (the snapshot covers them), and of the rest only the *final* op
    /// per key survives — ops are full-row puts and deletes, so
    /// last-write-wins folding preserves replay semantics exactly. This
    /// bounds log growth between checkpoints without paying a snapshot
    /// rewrite; overwrite-heavy tables (request state machines, usage
    /// counters) shrink the most. Leaves the log untouched when the
    /// fold wouldn't shrink it.
    pub fn compact_wal(&self) -> Result<CompactStats> {
        let (wal_handle, dir) = self.wal_binding("compact_wal")?;
        let _io = self.core.ckpt_io.lock().unwrap();
        let snap = wal::snapshot_file(&dir, self.core.name);
        let snap_seq = match wal::read_frames(&snap) {
            Ok(frames) => frames.first().and_then(|h| h.opt_u64("ckpt")).unwrap_or(0),
            Err(_) => 0, // unreadable manifest: fold conservatively from seq 0
        };
        let mut decode_err: Option<RucioError> = None;
        let mut ops_dropped = 0u64;
        let result = wal_handle.rewrite_locked(|records| {
            let mut last: BTreeMap<V::Key, (usize, Json)> = BTreeMap::new();
            let mut max_seq = 0u64;
            let mut ops_seen = 0u64;
            let mut order = 0usize;
            for rec in records {
                if rec.payload.opt_str("k") != Some("c") {
                    continue; // barriers are re-derived below
                }
                let Some(ops) = rec.payload.get("ops").and_then(Json::as_arr) else {
                    continue;
                };
                ops_seen += ops.len() as u64;
                if rec.seq <= snap_seq {
                    continue; // covered by the snapshot
                }
                max_seq = max_seq.max(rec.seq);
                for op in ops {
                    let key = match op.opt_str("o") {
                        Some("u") => op
                            .get("row")
                            .ok_or_else(|| {
                                RucioError::DatabaseError("wal put op without row".into())
                            })
                            .and_then(V::row_from_json)
                            .map(|r| r.key()),
                        Some("r") => op
                            .get("key")
                            .ok_or_else(|| {
                                RucioError::DatabaseError("wal del op without key".into())
                            })
                            .and_then(V::key_from_json),
                        other => Err(RucioError::DatabaseError(format!(
                            "unknown wal op kind {other:?}"
                        ))),
                    };
                    match key {
                        Ok(k) => {
                            last.insert(k, (order, op.clone()));
                            order += 1;
                        }
                        Err(e) => {
                            decode_err = Some(e);
                            return None;
                        }
                    }
                }
            }
            let mut payloads = Vec::new();
            if snap_seq > 0 {
                payloads.push(Json::obj().with("k", "b").with("seq", snap_seq));
            }
            if !last.is_empty() {
                let mut ops: Vec<(usize, Json)> = last.into_values().collect();
                ops.sort_unstable_by_key(|(o, _)| *o);
                let ops: Vec<Json> = ops.into_iter().map(|(_, op)| op).collect();
                ops_seen -= ops.len() as u64;
                payloads
                    .push(Json::obj().with("k", "c").with("seq", max_seq).with("ops", Json::Arr(ops)));
            }
            if payloads.len() >= records.len() && ops_seen == 0 {
                return None; // nothing to gain
            }
            ops_dropped = ops_seen;
            Some(payloads)
        })?;
        if let Some(e) = decode_err {
            return Err(e);
        }
        let mut stats = CompactStats::default();
        if let Some((bytes_before, records_before, bytes_after, records_after)) = result {
            stats.bytes_before = bytes_before;
            stats.records_before = records_before;
            stats.bytes_after = bytes_after;
            stats.records_after = records_after;
            stats.ops_dropped = ops_dropped;
        }
        Ok(stats)
    }

    /// Cold-boot this (empty) table from a snapshot plus the WAL suffix
    /// after the snapshot's barrier. Missing files read as empty — a
    /// fresh directory recovers to a fresh table. Two snapshot layouts
    /// are understood: the current manifest (`shardref` frames pointing
    /// at per-shard files) and the legacy inline form (`shard` frames
    /// with rows embedded). When the manifest's shard count matches this
    /// table's, snapshot rows land with their shards left *clean*, so a
    /// post-recovery checkpoint skips them; any other path (legacy,
    /// re-sharded layout, WAL replay) marks shards dirty. Every index
    /// already attached is rebuilt through the normal maintenance hooks;
    /// a torn final WAL record is detected by checksum and discarded
    /// whole.
    pub fn recover(&self, snapshot: &Path, wal_path: &Path) -> Result<RecoverStats> {
        if !self.is_empty() {
            return Err(RucioError::DatabaseError(format!(
                "table {}: recover requires an empty table",
                self.core.name
            )));
        }
        let mut stats = RecoverStats::default();
        if snapshot.exists() {
            let frames = wal::read_frames(snapshot)?;
            let mut it = frames.into_iter();
            let header = it.next().ok_or_else(|| {
                RucioError::DatabaseError(format!("table {}: empty snapshot", self.core.name))
            })?;
            if header.opt_str("k") != Some("snap") {
                return Err(RucioError::DatabaseError(format!(
                    "table {}: malformed snapshot header",
                    self.core.name
                )));
            }
            stats.snapshot_seq = header.opt_u64("ckpt").unwrap_or(0);
            let manifest_shards = header.opt_u64("shards").unwrap_or(0) as usize;
            let same_layout = manifest_shards == self.core.shards.len();
            let mut shardrefs = false;
            for shard_frame in it {
                match shard_frame.opt_str("k") {
                    Some("shardref") => shardrefs = true,
                    // Legacy inline layout: rows embedded in the
                    // manifest itself, no per-shard files on disk —
                    // shards must come up dirty so the next checkpoint
                    // materializes them.
                    Some("shard") => {
                        let rows =
                            shard_frame.get("rows").and_then(Json::as_arr).ok_or_else(|| {
                                RucioError::DatabaseError(format!(
                                    "table {}: snapshot shard without rows",
                                    self.core.name
                                ))
                            })?;
                        for rj in rows {
                            self.load_row(V::row_from_json(rj)?, true);
                            stats.snapshot_rows += 1;
                        }
                    }
                    _ => continue,
                }
            }
            if shardrefs {
                let dir = snapshot.parent().unwrap_or_else(|| Path::new("."));
                for i in 0..manifest_shards {
                    let path = wal::shard_snapshot_file(dir, self.core.name, i);
                    if !path.exists() {
                        continue; // empty shard at checkpoint time
                    }
                    for frame in wal::read_frames(&path)? {
                        if frame.opt_str("k") != Some("shard") {
                            continue;
                        }
                        let rows = frame.get("rows").and_then(Json::as_arr).ok_or_else(|| {
                            RucioError::DatabaseError(format!(
                                "table {}: shard file without rows",
                                self.core.name
                            ))
                        })?;
                        for rj in rows {
                            self.load_row(V::row_from_json(rj)?, !same_layout);
                            stats.snapshot_rows += 1;
                        }
                    }
                }
            }
        }
        if wal_path.exists() {
            let scan = wal::read_records(wal_path)?;
            stats.torn_tail = scan.torn;
            for rec in scan.records {
                if rec.payload.opt_str("k") != Some("c") {
                    continue; // barrier
                }
                if rec.seq <= stats.snapshot_seq {
                    continue; // already covered by the snapshot
                }
                stats.replayed_records += 1;
                for op in wal::decode_ops::<V>(&rec.payload)? {
                    match op {
                        ReplayOp::Put(row) => self.load_row(row, true),
                        ReplayOp::Del(key) => self.unload_row(&key),
                    }
                    stats.replayed_ops += 1;
                }
            }
        }
        Ok(stats)
    }

    /// Convenience: recover from the standard file names under `dir`.
    pub fn recover_from_dir(&self, dir: &Path) -> Result<RecoverStats> {
        self.recover(
            &wal::snapshot_file(dir, self.core.name),
            &wal::wal_file(dir, self.core.name),
        )
    }

    /// Live WAL shape, or `None` when no WAL is attached.
    pub fn wal_stats(&self) -> Option<WalStats> {
        self.core.wal.read().unwrap().as_ref().map(|b| b.wal.stats())
    }
}

impl<V: Durable> TablePersist for Table<V> {
    fn table_name(&self) -> &'static str {
        self.core.name
    }

    fn checkpoint(&self) -> Result<CheckpointStats> {
        Table::checkpoint(self)
    }

    fn wal_stats(&self) -> Option<WalStats> {
        Table::wal_stats(self)
    }

    fn needs_checkpoint(&self) -> bool {
        Table::needs_checkpoint(self)
    }

    fn compact_wal(&self) -> Result<CompactStats> {
        Table::compact_wal(self)
    }

    fn enforce_budget(&self) -> Result<usize> {
        Table::enforce_budget(self)
    }

    fn spill_stats(&self) -> SpillStats {
        Table::spill_stats(self)
    }
}

struct IndexInner<V: Row, IK: Ord> {
    map: BTreeMap<IK, BTreeSet<V::Key>>,
}

struct IndexMaintImpl<V: Row, IK: Ord> {
    extract: Box<dyn Fn(&V) -> Option<IK> + Send + Sync>,
    inner: RwLock<IndexInner<V, IK>>,
}

impl<V: Row, IK: Ord + Clone + Send + Sync + 'static> IndexMaint<V> for IndexMaintImpl<V, IK> {
    fn on_insert(&self, row: &V) {
        if let Some(ik) = (self.extract)(row) {
            self.inner
                .write()
                .unwrap()
                .map
                .entry(ik)
                .or_default()
                .insert(row.key());
        }
    }

    fn on_remove(&self, row: &V) {
        if let Some(ik) = (self.extract)(row) {
            let mut inner = self.inner.write().unwrap();
            if let Some(set) = inner.map.get_mut(&ik) {
                set.remove(&row.key());
                if set.is_empty() {
                    inner.map.remove(&ik);
                }
            }
        }
    }
}

/// A secondary index over a [`Table`]: maps an extracted key to the set of
/// primary keys. Rows whose extractor returns `None` are simply not indexed
/// (partial index — e.g. "only STUCK rules", the hot daemon queues).
pub struct Index<V: Row, IK: Ord + Clone + Send + Sync + 'static> {
    maint: Arc<IndexMaintImpl<V, IK>>,
}

impl<V: Row, IK: Ord + Clone + Send + Sync + 'static> Index<V, IK> {
    pub fn new<F: Fn(&V) -> Option<IK> + Send + Sync + 'static>(extract: F) -> Self {
        Index {
            maint: Arc::new(IndexMaintImpl {
                extract: Box::new(extract),
                inner: RwLock::new(IndexInner { map: BTreeMap::new() }),
            }),
        }
    }

    /// Primary keys with exactly this index key.
    pub fn get(&self, ik: &IK) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.iter().cloned().collect())
            .unwrap_or_default()
    }

    /// Up to `limit` primary keys with this index key.
    pub fn get_limit(&self, ik: &IK, limit: usize) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.iter().take(limit).cloned().collect())
            .unwrap_or_default()
    }

    /// Primary keys for index keys in `[lo, hi)` — range scans (e.g.
    /// "expiration timestamp before now", the reaper/judge work queues).
    pub fn range(&self, lo: &IK, hi: &IK) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .range(lo.clone()..hi.clone())
            .flat_map(|(_, s)| s.iter().cloned())
            .collect()
    }

    /// Up to `limit` primary keys for index keys in `[lo, hi)`, smallest
    /// index keys first (FIFO work queues keyed by timestamp).
    pub fn range_limit(&self, lo: &IK, hi: &IK, limit: usize) -> Vec<V::Key> {
        let inner = self.maint.inner.read().unwrap();
        let mut out = Vec::new();
        for (_, s) in inner.map.range(lo.clone()..hi.clone()) {
            for k in s {
                out.push(k.clone());
                if out.len() >= limit {
                    return out;
                }
            }
        }
        out
    }

    pub fn count(&self, ik: &IK) -> usize {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Number of distinct index keys.
    pub fn cardinality(&self) -> usize {
        self.maint.inner.read().unwrap().map.len()
    }

    /// Total indexed rows.
    pub fn len(&self) -> usize {
        self.maint.inner.read().unwrap().map.values().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Distinct index keys (snapshot).
    pub fn index_keys(&self) -> Vec<IK> {
        self.maint.inner.read().unwrap().map.keys().cloned().collect()
    }
}

struct MultiIndexMaintImpl<V: Row, IK: Ord> {
    extract: Box<dyn Fn(&V) -> Vec<IK> + Send + Sync>,
    inner: RwLock<IndexInner<V, IK>>,
}

impl<V: Row, IK: Ord + Clone + Send + Sync + 'static> IndexMaint<V> for MultiIndexMaintImpl<V, IK> {
    fn on_insert(&self, row: &V) {
        let iks = (self.extract)(row);
        if iks.is_empty() {
            return;
        }
        let mut inner = self.inner.write().unwrap();
        let pk = row.key();
        for ik in iks {
            inner.map.entry(ik).or_default().insert(pk.clone());
        }
    }

    fn on_remove(&self, row: &V) {
        let iks = (self.extract)(row);
        if iks.is_empty() {
            return;
        }
        let mut inner = self.inner.write().unwrap();
        let pk = row.key();
        for ik in iks {
            if let Some(set) = inner.map.get_mut(&ik) {
                set.remove(&pk);
                if set.is_empty() {
                    inner.map.remove(&ik);
                }
            }
        }
    }
}

/// A multi-key secondary index: one row maps to *many* index keys — the
/// inverted-index shape (paper §2.2 metadata: each `(scope, key, value)`
/// triple of a DID's metadata map posts the DID under that triple).
/// Maintained by the owning table exactly like [`Index`], across every
/// mutation path (row-at-a-time, batches, `update_bulk`, recovery
/// replay), so entries can never go stale relative to the rows.
pub struct MultiIndex<V: Row, IK: Ord + Clone + Send + Sync + 'static> {
    maint: Arc<MultiIndexMaintImpl<V, IK>>,
}

impl<V: Row, IK: Ord + Clone + Send + Sync + 'static> MultiIndex<V, IK> {
    pub fn new<F: Fn(&V) -> Vec<IK> + Send + Sync + 'static>(extract: F) -> Self {
        MultiIndex {
            maint: Arc::new(MultiIndexMaintImpl {
                extract: Box::new(extract),
                inner: RwLock::new(IndexInner { map: BTreeMap::new() }),
            }),
        }
    }

    /// Primary keys posted under exactly this index key, in key order.
    pub fn get(&self, ik: &IK) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.iter().cloned().collect())
            .unwrap_or_default()
    }

    /// Rows posted under this index key.
    pub fn count(&self, ik: &IK) -> usize {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Primary keys for index keys inside the given bounds, in index-key
    /// order (the planner's range-predicate path, e.g. `run >= 358000`).
    pub fn range_bounds(&self, lo: Bound<&IK>, hi: Bound<&IK>) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .range((lo, hi))
            .flat_map(|(_, s)| s.iter().cloned())
            .collect()
    }

    /// Rows posted under index keys inside the bounds (planner selectivity
    /// estimate; O(distinct index keys in range)).
    pub fn count_range(&self, lo: Bound<&IK>, hi: Bound<&IK>) -> usize {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .range((lo, hi))
            .map(|(_, s)| s.len())
            .sum()
    }

    /// Number of distinct index keys.
    pub fn cardinality(&self) -> usize {
        self.maint.inner.read().unwrap().map.len()
    }

    /// Total postings (row, index-key) pairs.
    pub fn len(&self) -> usize {
        self.maint.inner.read().unwrap().map.values().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Distinct index keys (snapshot).
    pub fn index_keys(&self) -> Vec<IK> {
        self.maint.inner.read().unwrap().map.keys().cloned().collect()
    }

    /// `(index key, posting count)` pairs in index-key order — one pass
    /// under one read lock, so reports see a consistent snapshot instead
    /// of paying a lock round-trip per key.
    pub fn key_counts(&self) -> Vec<(IK, usize)> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .iter()
            .map(|(k, s)| (k.clone(), s.len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::forall;
    use crate::db::wal::{self as walmod, WalOptions};
    use crate::jsonx::Json;

    #[derive(Clone, Debug, PartialEq)]
    struct Item {
        id: u64,
        state: &'static str,
        rse: String,
    }

    impl Row for Item {
        type Key = u64;
        fn key(&self) -> u64 {
            self.id
        }
    }

    fn item(id: u64, state: &'static str, rse: &str) -> Item {
        Item { id, state, rse: rse.to_string() }
    }

    #[test]
    fn crud_basics() {
        let t: Table<Item> = Table::new("items");
        t.insert(item(1, "new", "A"), 0).unwrap();
        t.insert(item(2, "new", "B"), 0).unwrap();
        assert_eq!(t.len(), 2);
        assert!(t.insert(item(1, "dup", "A"), 0).is_err());
        assert_eq!(t.get(&1).unwrap().state, "new");
        t.update(&1, 1, |r| r.state = "done");
        assert_eq!(t.get(&1).unwrap().state, "done");
        assert_eq!(t.remove(&2, 2).unwrap().rse, "B");
        assert_eq!(t.len(), 1);
        assert!(t.remove(&2, 3).is_none());
    }

    #[test]
    fn index_tracks_mutations() {
        let t: Table<Item> = Table::new("items");
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();

        t.insert(item(1, "new", "A"), 0).unwrap();
        t.insert(item(2, "new", "B"), 0).unwrap();
        t.insert(item(3, "done", "A"), 0).unwrap();
        assert_eq!(by_state.get(&"new"), vec![1, 2]);
        assert_eq!(by_state.count(&"done"), 1);

        t.update(&1, 1, |r| r.state = "done");
        assert_eq!(by_state.get(&"new"), vec![2]);
        assert_eq!(by_state.get(&"done"), vec![1, 3]);

        t.remove(&3, 2);
        assert_eq!(by_state.get(&"done"), vec![1]);
    }

    #[test]
    fn partial_index_skips_none() {
        let t: Table<Item> = Table::new("items");
        let stuck: Index<Item, u64> =
            Index::new(|r: &Item| if r.state == "stuck" { Some(r.id) } else { None });
        t.add_index(&stuck).unwrap();
        t.insert(item(1, "new", "A"), 0).unwrap();
        t.insert(item(2, "stuck", "A"), 0).unwrap();
        assert_eq!(stuck.len(), 1);
        t.update(&1, 1, |r| r.state = "stuck");
        assert_eq!(stuck.len(), 2);
        t.update(&2, 2, |r| r.state = "done");
        assert_eq!(stuck.len(), 1);
    }

    #[test]
    fn range_queries_work() {
        let t: Table<Item> = Table::new("items");
        let by_id_band: Index<Item, u64> = Index::new(|r: &Item| Some(r.id * 10));
        t.add_index(&by_id_band).unwrap();
        for i in 1..=10 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        let keys = by_id_band.range(&20, &51); // ids 2..=5
        assert_eq!(keys, vec![2, 3, 4, 5]);
        let limited = by_id_band.range_limit(&0, &1000, 3);
        assert_eq!(limited.len(), 3);
        assert_eq!(limited, vec![1, 2, 3]); // smallest index keys first
    }

    #[test]
    fn add_index_backfills_nonempty_table() {
        let t: Table<Item> = Table::new("items");
        t.insert(item(1, "new", "A"), 0).unwrap();
        t.insert(item(2, "done", "B"), 0).unwrap();
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();
        // back-fill saw the pre-existing rows
        assert_eq!(by_state.get(&"new"), vec![1]);
        assert_eq!(by_state.get(&"done"), vec![2]);
        // and the index stays live for subsequent mutations
        t.update(&1, 1, |r| r.state = "done");
        assert_eq!(by_state.get(&"done"), vec![1, 2]);
        t.remove(&2, 2);
        assert_eq!(by_state.get(&"done"), vec![1]);
    }

    #[test]
    fn history_records_ops() {
        let t: Table<Item> = Table::new("items").with_history();
        t.insert(item(1, "new", "A"), 10).unwrap();
        t.update(&1, 20, |r| r.state = "done");
        t.remove(&1, 30);
        let h = t.history();
        assert_eq!(h.len(), 3);
        assert_eq!(h[0].1, Op::Insert);
        assert_eq!(h[1].1, Op::Update);
        assert_eq!(h[2].1, Op::Delete);
        assert_eq!(h[2].0, 30);
    }

    #[test]
    fn upsert_replaces_and_reindexes() {
        let t: Table<Item> = Table::new("items");
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();
        t.upsert(item(1, "new", "A"), 0);
        t.upsert(item(1, "done", "B"), 1);
        assert_eq!(t.len(), 1);
        assert_eq!(by_state.count(&"new"), 0);
        assert_eq!(by_state.count(&"done"), 1);
    }

    #[test]
    fn scan_limit_stops_early() {
        let t: Table<Item> = Table::new("items");
        for i in 0..100 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        assert_eq!(t.scan_limit(7, |_| true).len(), 7);
        assert_eq!(t.scan(|r| r.id < 5).len(), 5);
    }

    #[test]
    fn scan_is_globally_ordered_across_shards() {
        let t: Table<Item> = Table::new("items").with_shards(7);
        // insert in a scrambled order so shard-local order != insert order
        for i in [44u64, 3, 99, 12, 8, 71, 23, 55, 0, 67, 31] {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        let ids: Vec<u64> = t.scan(|_| true).into_iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort();
        assert_eq!(ids, sorted, "merge scan yields global key order");
        assert_eq!(t.keys(), sorted);
    }

    #[test]
    fn scan_page_walks_whole_table() {
        let t: Table<Item> = Table::new("items").with_shards(5);
        for i in 0..23 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        let mut seen = Vec::new();
        let mut cursor: Option<u64> = None;
        let mut pages = 0;
        loop {
            let page = t.scan_page(cursor.as_ref(), 7);
            seen.extend(page.rows.iter().map(|r| r.id));
            pages += 1;
            match page.next_cursor {
                Some(c) => cursor = Some(c),
                None => break,
            }
            assert!(pages < 100, "cursor must make progress");
        }
        assert_eq!(seen, (0..23).collect::<Vec<u64>>());
        assert_eq!(pages, 4, "23 rows / 7 per page");
        // empty table: one empty page, no cursor
        let empty: Table<Item> = Table::new("e");
        let page = empty.scan_page(None, 10);
        assert!(page.rows.is_empty() && page.next_cursor.is_none());
    }

    #[test]
    fn insert_bulk_is_atomic_on_duplicates() {
        let t: Table<Item> = Table::new("items");
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();
        t.insert(item(5, "old", "A"), 0).unwrap();
        // batch containing a duplicate of row 5 → nothing applied
        let err = t.insert_bulk(vec![item(1, "new", "A"), item(5, "new", "A")], 1);
        assert!(err.is_err());
        assert_eq!(t.len(), 1);
        assert!(t.get(&1).is_none());
        assert_eq!(by_state.count(&"new"), 0, "no index leak from failed batch");
        // in-batch duplicate also rejected
        assert!(t.insert_bulk(vec![item(2, "new", "A"), item(2, "new", "B")], 1).is_err());
        // clean batch applies
        assert_eq!(t.insert_bulk(vec![item(1, "new", "A"), item(2, "new", "B")], 2).unwrap(), 2);
        assert_eq!(t.len(), 3);
        assert_eq!(by_state.get(&"new"), vec![1, 2]);
    }

    #[test]
    fn batch_ops_update_indexes_history_and_len() {
        let t: Table<Item> = Table::new("items").with_history().with_shards(3);
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();
        let mut batch = Batch::new();
        batch.insert(item(1, "new", "A"));
        batch.insert(item(2, "new", "B"));
        batch.upsert(item(2, "done", "B"));
        batch.remove(1);
        batch.remove(42); // missing: skipped
        let s = t.apply(batch, 7).unwrap();
        assert_eq!((s.inserted, s.updated), (2, 1));
        assert_eq!(s.removed.len(), 1);
        assert_eq!(s.removed[0].id, 1);
        assert_eq!(t.len(), 1);
        assert_eq!(by_state.get(&"done"), vec![2]);
        assert_eq!(by_state.count(&"new"), 0);
        let h = t.history();
        let ops: Vec<Op> = h.iter().map(|(_, op, _)| *op).collect();
        assert_eq!(ops, vec![Op::Insert, Op::Insert, Op::Update, Op::Delete]);
    }

    #[test]
    fn update_bulk_applies_one_commit() {
        let t: Table<Item> = Table::new("items").with_shards(4);
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();
        for i in 0..10 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        let keys: Vec<u64> = vec![1, 3, 5, 77]; // 77 missing → skipped
        let updated = t.update_bulk(&keys, 1, |r| r.state = "done");
        assert_eq!(updated.len(), 3);
        assert_eq!(by_state.get(&"done"), vec![1, 3, 5]);
        assert_eq!(by_state.count(&"new"), 7);
    }

    #[test]
    fn remove_bulk_returns_removed_rows() {
        let t: Table<Item> = Table::new("items").with_shards(4);
        for i in 0..6 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        let removed = t.remove_bulk(&[4, 1, 9], 1);
        assert_eq!(removed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 1]);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn len_counter_tracks_live_rows() {
        let t: Table<Item> = Table::new("items").with_shards(4);
        let counter = t.len_counter();
        assert_eq!(counter(), 0);
        t.insert_bulk((0..50).map(|i| item(i, "new", "A")).collect(), 0).unwrap();
        assert_eq!(counter(), 50);
        t.remove_bulk(&(0..20).collect::<Vec<u64>>(), 1);
        assert_eq!(counter(), 30);
        t.upsert(item(7, "done", "B"), 2); // replace: no growth
        assert_eq!(counter(), 30);
    }

    #[test]
    fn read_projects_without_whole_row() {
        let t: Table<Item> = Table::new("items");
        t.insert(item(1, "new", "SITE-A"), 0).unwrap();
        assert_eq!(t.read(&1, |r| r.rse.clone()), Some("SITE-A".to_string()));
        assert_eq!(t.read(&1, |r| r.state), Some("new"));
        assert_eq!(t.read(&2, |r| r.state), None);
    }

    #[test]
    fn multi_index_tracks_all_mutation_paths() {
        // index every character of `rse` — one row, many postings
        let t: Table<Item> = Table::new("items").with_shards(3);
        let by_char: MultiIndex<Item, char> =
            MultiIndex::new(|r: &Item| r.rse.chars().collect());
        t.add_multi_index(&by_char).unwrap();

        t.insert(item(1, "new", "ab"), 0).unwrap();
        t.insert(item(2, "new", "bc"), 0).unwrap();
        assert_eq!(by_char.get(&'a'), vec![1]);
        assert_eq!(by_char.get(&'b'), vec![1, 2]);
        assert_eq!(by_char.count(&'c'), 1);
        assert_eq!(by_char.len(), 4);
        assert_eq!(by_char.cardinality(), 3);

        // update refreshes every posting
        t.update(&1, 1, |r| r.rse = "cd".into());
        assert_eq!(by_char.get(&'a'), Vec::<u64>::new());
        assert_eq!(by_char.get(&'c'), vec![1, 2]);
        assert_eq!(by_char.get(&'d'), vec![1]);

        // remove cleans all postings, empty posting sets disappear
        t.remove(&2, 2);
        assert_eq!(by_char.get(&'b'), Vec::<u64>::new());
        assert_eq!(by_char.cardinality(), 2);

        // batch ops maintain it too
        let mut batch = Batch::new();
        batch.insert(item(3, "new", "xy"));
        batch.upsert(item(1, "new", "x"));
        batch.remove(3);
        t.apply(batch, 3).unwrap();
        assert_eq!(by_char.get(&'x'), vec![1]);
        assert_eq!(by_char.count(&'y'), 0);
        assert_eq!(by_char.count(&'d'), 0);
    }

    #[test]
    fn multi_index_backfills_and_ranges() {
        let t: Table<Item> = Table::new("items");
        t.insert(item(1, "new", "ac"), 0).unwrap();
        t.insert(item(2, "new", "ce"), 0).unwrap();
        let by_char: MultiIndex<Item, char> =
            MultiIndex::new(|r: &Item| r.rse.chars().collect());
        t.add_multi_index(&by_char).unwrap();
        assert_eq!(by_char.len(), 4, "back-fill saw pre-existing rows");
        assert_eq!(by_char.key_counts(), vec![('a', 1), ('c', 2), ('e', 1)]);
        // range queries over index keys
        let keys = by_char.range_bounds(Bound::Included(&'b'), Bound::Included(&'d'));
        assert_eq!(keys, vec![1, 2]); // 'c' posts both
        assert_eq!(by_char.count_range(Bound::Excluded(&'c'), Bound::Unbounded), 1); // 'e'
        // empty extraction is simply not indexed
        t.insert(item(3, "new", ""), 1).unwrap();
        assert_eq!(by_char.len(), 4);
    }

    #[test]
    fn prop_index_consistent_under_random_ops() {
        forall(60, |g| {
            let t: Table<Item> = Table::new("items").with_shards(g.usize(1, 9));
            let states = ["a", "b", "c"];
            let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
            t.add_index(&by_state).unwrap();
            let mut live = std::collections::BTreeMap::new();
            for step in 0..g.usize(10, 200) {
                let id = g.u64(0, 30);
                match g.usize(0, 3) {
                    0 => {
                        let st = *g.pick(&states);
                        if t.insert(item(id, st, "X"), step as i64).is_ok() {
                            live.insert(id, st);
                        }
                    }
                    1 => {
                        let st = *g.pick(&states);
                        if t.update(&id, step as i64, |r| r.state = st).is_some() {
                            live.insert(id, st);
                        }
                    }
                    _ => {
                        t.remove(&id, step as i64);
                        live.remove(&id);
                    }
                }
            }
            // Model equivalence: index contents == reference map.
            for st in states {
                let mut expect: Vec<u64> = live
                    .iter()
                    .filter(|(_, v)| **v == st)
                    .map(|(k, _)| *k)
                    .collect();
                expect.sort();
                assert_eq!(by_state.get(&st), expect, "state {st}");
            }
            assert_eq!(t.len(), live.len());
        });
    }

    /// Shard-count invariance: a table with N shards is observationally
    /// identical to the single-map (1-shard) layout under a randomized op
    /// sequence — same scan order, length, history, index contents, and
    /// cursor pagination. This is the ordered-scan-semantics guarantee the
    /// sharding refactor must preserve.
    #[test]
    fn prop_sharded_table_matches_single_map() {
        forall(40, |g| {
            let n_shards = g.usize(2, 17);
            let sharded: Table<Item> =
                Table::new("sharded").with_history().with_shards(n_shards);
            let reference: Table<Item> =
                Table::new("reference").with_history().with_shards(1);
            let idx_s: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
            let idx_r: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
            sharded.add_index(&idx_s).unwrap();
            reference.add_index(&idx_r).unwrap();
            let states = ["a", "b", "c"];

            for step in 0..g.usize(20, 150) {
                let now = step as i64;
                match g.usize(0, 5) {
                    0 => {
                        let row = item(g.u64(0, 40), *g.pick(&states), "X");
                        let rs = sharded.insert(row.clone(), now).is_ok();
                        let rr = reference.insert(row, now).is_ok();
                        assert_eq!(rs, rr, "insert outcome diverged");
                    }
                    1 => {
                        let row = item(g.u64(0, 40), *g.pick(&states), "Y");
                        sharded.upsert(row.clone(), now);
                        reference.upsert(row, now);
                    }
                    2 => {
                        let id = g.u64(0, 40);
                        let st = *g.pick(&states);
                        let us = sharded.update(&id, now, |r| r.state = st);
                        let ur = reference.update(&id, now, |r| r.state = st);
                        assert_eq!(us.is_some(), ur.is_some());
                    }
                    3 => {
                        let id = g.u64(0, 40);
                        let rs = sharded.remove(&id, now);
                        let rr = reference.remove(&id, now);
                        assert_eq!(rs.is_some(), rr.is_some());
                    }
                    _ => {
                        // batch: a few inserts/upserts/removes in one commit
                        let mut bs = Batch::new();
                        let mut br = Batch::new();
                        for _ in 0..g.usize(1, 6) {
                            match g.usize(0, 3) {
                                0 => {
                                    let row = item(g.u64(41, 80), *g.pick(&states), "Z");
                                    bs.insert(row.clone());
                                    br.insert(row);
                                }
                                1 => {
                                    let row = item(g.u64(0, 80), *g.pick(&states), "Z");
                                    bs.upsert(row.clone());
                                    br.upsert(row);
                                }
                                _ => {
                                    let id = g.u64(0, 80);
                                    bs.remove(id);
                                    br.remove(id);
                                }
                            }
                        }
                        let as_ = sharded.apply(bs, now);
                        let ar = reference.apply(br, now);
                        assert_eq!(as_.is_ok(), ar.is_ok(), "batch outcome diverged");
                    }
                }
            }

            // Observational equivalence.
            assert_eq!(sharded.len(), reference.len());
            assert_eq!(sharded.keys(), reference.keys(), "global key order");
            assert_eq!(sharded.scan(|_| true), reference.scan(|_| true), "scan order + content");
            assert_eq!(
                sharded.scan_limit(5, |r| r.state == "a"),
                reference.scan_limit(5, |r| r.state == "a")
            );
            for st in states {
                assert_eq!(idx_s.get(&st), idx_r.get(&st), "index contents for {st}");
            }
            // history (same op sequence → identical logs)
            let hs = sharded.history();
            let hr = reference.history();
            assert_eq!(hs.len(), hr.len());
            for (a, b) in hs.iter().zip(hr.iter()) {
                assert_eq!((a.0, a.1), (b.0, b.1));
                assert_eq!(a.2, b.2);
            }
            // cursor pagination covers the same sequence
            let mut paged = Vec::new();
            let mut cursor: Option<u64> = None;
            loop {
                let page = sharded.scan_page(cursor.as_ref(), 4);
                paged.extend(page.rows.into_iter().map(|r| r.id));
                match page.next_cursor {
                    Some(c) => cursor = Some(c),
                    None => break,
                }
            }
            let flat: Vec<u64> = reference.scan(|_| true).into_iter().map(|r| r.id).collect();
            assert_eq!(paged, flat, "paged walk == flat ordered scan");
        });
    }

    #[test]
    fn concurrent_readers_and_writers() {
        use std::sync::Arc;
        let t: Arc<Table<Item>> = Arc::new(Table::new("items"));
        let mut handles = vec![];
        for w in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let id = w * 1000 + i;
                    t.insert(item(id, "new", "A"), 0).unwrap();
                    if i % 3 == 0 {
                        t.update(&id, 1, |r| r.state = "done");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 2000);
        let done = t.scan(|r| r.state == "done");
        assert_eq!(done.len(), 4 * 167);
    }

    #[test]
    fn concurrent_bulk_and_row_writers() {
        use std::sync::Arc;
        let t: Arc<Table<Item>> = Arc::new(Table::new("items").with_shards(4));
        let mut handles = vec![];
        for w in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                if w % 2 == 0 {
                    // bulk writer: 10 batches of 50
                    for b in 0..10u64 {
                        let rows: Vec<Item> = (0..50)
                            .map(|i| item(w * 10_000 + b * 50 + i, "new", "A"))
                            .collect();
                        t.insert_bulk(rows, 0).unwrap();
                    }
                } else {
                    // row-at-a-time writer
                    for i in 0..500u64 {
                        t.insert(item(w * 10_000 + i, "new", "A"), 0).unwrap();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 2000);
        let keys = t.keys();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn contention_counters_track_lock_traffic() {
        let t: Table<Item> = Table::new("items").with_shards(8);
        let probe = t.contention_probe();
        assert_eq!(probe().single_write_locks, 0);
        for i in 0..10 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        t.update(&3, 1, |r| r.state = "done");
        t.remove(&4, 2);
        let c = t.contention_stats();
        assert_eq!(c.shard_count, 8);
        assert_eq!(c.single_write_locks, 12);
        assert_eq!(c.bulk_commits, 0);
        // a bulk commit locks at most one shard per distinct key shard
        t.update_bulk(&[0, 1, 2], 3, |r| r.state = "done");
        let c = probe();
        assert_eq!(c.bulk_commits, 1);
        assert!(c.bulk_shards_locked >= 1 && c.bulk_shards_locked <= 3);
        // a single-key batch locks exactly one shard
        let mut batch = Batch::new();
        batch.upsert(item(50, "new", "B"));
        t.apply(batch, 4).unwrap();
        let c2 = t.contention_stats();
        assert_eq!(c2.bulk_commits, 2);
        assert_eq!(c2.bulk_shards_locked, c.bulk_shards_locked + 1);
    }

    #[test]
    fn bulk_commits_on_disjoint_shards_run_concurrently_and_stay_atomic() {
        use std::sync::Arc;
        // Many writers issuing small batches (each locking only its
        // touched shards) while a reader does full merged scans: every
        // scan must observe each batch's rows all-or-nothing
        // (batch = 3 rows with consecutive marker ids).
        let t: Arc<Table<Item>> = Arc::new(Table::new("items").with_shards(8));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = vec![];
        for w in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                for b in 0..50u64 {
                    let base = w * 1_000 + b * 3;
                    let mut batch = Batch::new();
                    for i in 0..3 {
                        batch.insert(item(base + i, "new", "A"));
                    }
                    t.apply(batch, 0).unwrap();
                }
            }));
        }
        let reader = {
            let t = t.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let ids: std::collections::BTreeSet<u64> =
                        t.scan(|_| true).into_iter().map(|r| r.id).collect();
                    for w in 0..4u64 {
                        for b in 0..50u64 {
                            let base = w * 1_000 + b * 3;
                            let present =
                                (0..3).filter(|i| ids.contains(&(base + i))).count();
                            assert!(
                                present == 0 || present == 3,
                                "torn batch visible: {present}/3 rows of batch {base}"
                            );
                        }
                    }
                }
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        reader.join().unwrap();
        assert_eq!(t.len(), 4 * 50 * 3);
    }

    // ------------------------------------------------------------------
    // durability: WAL + checkpoint + recovery
    // ------------------------------------------------------------------

    /// A minimal durable row for WAL tests.
    #[derive(Clone, Debug, PartialEq)]
    struct DRow {
        id: u64,
        val: String,
    }

    impl Row for DRow {
        type Key = u64;
        fn key(&self) -> u64 {
            self.id
        }
    }

    impl Durable for DRow {
        fn row_to_json(&self) -> Json {
            Json::obj().with("id", self.id).with("val", self.val.as_str())
        }
        fn row_from_json(j: &Json) -> Result<Self> {
            Ok(DRow { id: j.req_u64("id")?, val: j.req_str("val")?.to_string() })
        }
        fn key_to_json(key: &u64) -> Json {
            Json::from(*key)
        }
        fn key_from_json(j: &Json) -> Result<u64> {
            j.as_u64()
                .ok_or_else(|| RucioError::JsonError("bad u64 key".into()))
        }
    }

    fn drow(id: u64, val: &str) -> DRow {
        DRow { id, val: val.to_string() }
    }

    fn tmpdir(name: &str) -> PathBuf {
        static N: AtomicUsize = AtomicUsize::new(0);
        let i = N.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("rucio-table-{}-{name}-{i}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn contents(t: &Table<DRow>) -> BTreeMap<u64, String> {
        t.scan(|_| true).into_iter().map(|r| (r.id, r.val)).collect()
    }

    #[test]
    fn wal_checkpoint_recover_round_trip() {
        let dir = tmpdir("rt");
        let t: Table<DRow> = Table::new("d").with_shards(3);
        let by_val: Index<DRow, String> = Index::new(|r: &DRow| Some(r.val.clone()));
        t.add_index(&by_val).unwrap();
        t.attach_wal(&dir, WalOptions::default()).unwrap();

        for i in 0..20 {
            t.insert(drow(i, "a"), 0).unwrap();
        }
        t.update(&3, 1, |r| r.val = "b".into());
        t.remove(&4, 1);
        let ck = t.checkpoint().unwrap();
        assert_eq!(ck.rows, 19);
        // post-checkpoint mutations land in the (truncated) WAL suffix
        t.upsert(drow(100, "c"), 2);
        let mut batch = Batch::new();
        batch.upsert(drow(101, "c"));
        batch.remove(0);
        t.apply(batch, 3).unwrap();
        t.update_bulk(&[1, 2], 4, |r| r.val = "z".into());

        // recover into a table with a *different* shard count, index
        // attached up front: the hooks rebuild it during the load
        let r: Table<DRow> = Table::new("d").with_shards(7);
        let by_val_r: Index<DRow, String> = Index::new(|r: &DRow| Some(r.val.clone()));
        r.add_index(&by_val_r).unwrap();
        let stats = r.recover_from_dir(&dir).unwrap();
        assert_eq!(stats.snapshot_rows, 19);
        assert!(stats.replayed_records >= 3);
        assert!(!stats.torn_tail);

        assert_eq!(contents(&r), contents(&t));
        assert_eq!(r.len(), t.len());
        assert_eq!(r.keys(), t.keys());
        for v in ["a", "b", "c", "z"] {
            assert_eq!(by_val_r.get(&v.to_string()), by_val.get(&v.to_string()), "index {v}");
        }

        // a multi-index attached *after* recovery back-fills correctly
        let chars: MultiIndex<DRow, char> = MultiIndex::new(|r: &DRow| r.val.chars().collect());
        r.add_multi_index(&chars).unwrap();
        assert_eq!(chars.count(&'z'), 2);

        // the type-erased persistence handle drives checkpoints too
        r.attach_wal(&dir, WalOptions::default()).unwrap();
        let handle: Arc<dyn TablePersist> = Arc::new(r.clone());
        assert_eq!(handle.table_name(), "d");
        let ck2 = handle.checkpoint().unwrap();
        assert_eq!(ck2.rows, r.len());
        assert!(handle.wal_stats().unwrap().records_since_checkpoint == 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recover_without_snapshot_replays_full_wal() {
        let dir = tmpdir("nosnap");
        let t: Table<DRow> = Table::new("d");
        t.attach_wal(&dir, WalOptions { fsync: false, group_commit: false, leader: true })
            .unwrap();
        t.insert(drow(1, "a"), 0).unwrap();
        t.upsert(drow(2, "b"), 0);
        t.update(&1, 1, |r| r.val = "c".into());
        t.remove(&2, 2);
        let r: Table<DRow> = Table::new("d");
        let stats = r.recover_from_dir(&dir).unwrap();
        assert_eq!(stats.snapshot_rows, 0);
        assert_eq!(stats.replayed_ops, 4);
        assert_eq!(contents(&r), BTreeMap::from([(1, "c".to_string())]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recover_requires_empty_table() {
        let dir = tmpdir("nonempty");
        let t: Table<DRow> = Table::new("d");
        t.insert(drow(1, "a"), 0).unwrap();
        assert!(t.recover_from_dir(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wal_stats_reflect_appends_and_checkpoints() {
        let dir = tmpdir("stats");
        let t: Table<DRow> = Table::new("d");
        assert!(t.wal_stats().is_none(), "no WAL attached yet");
        t.attach_wal(&dir, WalOptions::default()).unwrap();
        t.insert(drow(1, "a"), 0).unwrap();
        t.upsert_bulk(vec![drow(2, "b"), drow(3, "b")], 0);
        let s = t.wal_stats().unwrap();
        assert_eq!(s.records, 2, "group commit: bulk batch is one record");
        assert_eq!(s.records_since_checkpoint, 2);
        assert!(s.bytes > 0);
        t.checkpoint().unwrap();
        let s = t.wal_stats().unwrap();
        assert_eq!(s.records_since_checkpoint, 0);
        assert!(s.last_checkpoint_seq > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The crash-safety property: cut the WAL at an *arbitrary byte*
    /// (simulating a crash mid-write, including mid-batch) and recovery
    /// must land on exactly the state after some prefix of commits —
    /// never a half-applied commit. Runs with group commit on and off,
    /// random shard counts, and interleaved checkpoints.
    #[test]
    fn prop_torn_tail_recovers_to_a_commit_prefix() {
        forall(25, |g| {
            let dir = tmpdir("prop");
            let group = g.bool();
            let leader = g.bool();
            let t: Table<DRow> = Table::new("d").with_shards(g.usize(1, 5));
            t.attach_wal(&dir, WalOptions { fsync: false, group_commit: group, leader })
                .unwrap();
            let mut model: BTreeMap<u64, String> = BTreeMap::new();
            // state after every commit (batch-granular under group
            // commit, op-granular otherwise)
            let mut states: Vec<BTreeMap<u64, String>> = vec![model.clone()];
            for step in 0..g.usize(5, 40) {
                let now = step as i64;
                if g.chance(0.1) {
                    t.checkpoint().unwrap();
                    continue;
                }
                if g.chance(0.3) {
                    let mut batch = Batch::new();
                    let mut ops: Vec<(u64, Option<String>)> = Vec::new();
                    for _ in 0..g.usize(1, 5) {
                        let id = g.u64(0, 15);
                        if g.bool() {
                            let val = g.ident(1..6);
                            batch.upsert(drow(id, &val));
                            ops.push((id, Some(val)));
                        } else {
                            batch.remove(id);
                            ops.push((id, None));
                        }
                    }
                    t.apply(batch, now).unwrap();
                    for (id, v) in ops {
                        match v {
                            Some(val) => {
                                model.insert(id, val);
                            }
                            None => {
                                model.remove(&id);
                            }
                        }
                        if !group {
                            states.push(model.clone());
                        }
                    }
                    if group {
                        states.push(model.clone());
                    }
                } else {
                    let id = g.u64(0, 15);
                    if g.bool() {
                        let val = g.ident(1..6);
                        t.upsert(drow(id, &val), now);
                        model.insert(id, val);
                    } else {
                        t.remove(&id, now);
                        model.remove(&id);
                    }
                    states.push(model.clone());
                }
            }
            // crash: truncate the log at an arbitrary byte
            let wal_path = walmod::wal_file(&dir, "d");
            let len = std::fs::metadata(&wal_path).unwrap().len();
            if len > 0 {
                let cut = g.u64(0, len);
                let f = std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap();
                f.set_len(cut).unwrap();
            }
            let r: Table<DRow> = Table::new("d").with_shards(g.usize(1, 5));
            r.recover_from_dir(&dir).unwrap();
            let recovered = contents(&r);
            assert!(
                states.contains(&recovered),
                "recovered state must equal a commit prefix (got {recovered:?})"
            );
            std::fs::remove_dir_all(&dir).ok();
        });
    }

    // ------------------------------------------------------------------
    // paged mode: spill-to-disk, incremental checkpoints, WAL compaction
    // ------------------------------------------------------------------

    /// Satellite regression: `Table::checkpoint` must not hold shard
    /// read locks through the snapshot file IO. The test-only
    /// `ckpt_io_hook` parks a checkpoint thread *inside* its IO phase;
    /// a concurrent writer must still commit while it is parked — under
    /// the old hold-locks-through-IO code this test deadlocks the
    /// writer until the (blocked) IO finishes.
    #[test]
    fn writers_progress_during_checkpoint_io() {
        use std::sync::atomic::AtomicBool;
        let dir = tmpdir("ckptio");
        let t: Table<DRow> = Table::new("d").with_shards(4);
        t.attach_wal(&dir, WalOptions { fsync: false, group_commit: false, leader: true })
            .unwrap();
        for i in 0..20 {
            t.insert(drow(i, "a"), 0).unwrap();
        }
        let in_io = Arc::new(AtomicBool::new(false));
        let release = Arc::new(AtomicBool::new(false));
        {
            let in_io = in_io.clone();
            let release = release.clone();
            *t.core.ckpt_io_hook.write().unwrap() = Some(Box::new(move || {
                in_io.store(true, Ordering::SeqCst);
                while !release.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
            }));
        }
        let ckpt = {
            let t = t.clone();
            std::thread::spawn(move || t.checkpoint())
        };
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !in_io.load(Ordering::SeqCst) {
            assert!(std::time::Instant::now() < deadline, "checkpoint never reached its IO phase");
            std::thread::yield_now();
        }
        // The snapshot IO is now parked. A writer must make progress.
        let writer = {
            let t = t.clone();
            std::thread::spawn(move || {
                t.insert(drow(100, "w"), 1).unwrap();
                assert!(t.update(&3, 1, |r| r.val = "w".into()).is_some());
            })
        };
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while t.get(&100).is_none() {
            assert!(
                std::time::Instant::now() < deadline,
                "writer blocked behind checkpoint IO (shard locks held through IO?)"
            );
            std::thread::yield_now();
        }
        assert!(!release.load(Ordering::SeqCst), "writer committed while IO was parked");
        release.store(true, Ordering::SeqCst);
        writer.join().unwrap();
        ckpt.join().unwrap().unwrap();
        *t.core.ckpt_io_hook.write().unwrap() = None;
        // Nothing lost: the mid-checkpoint commits sit past the barrier
        // and replay from the preserved WAL suffix.
        let r: Table<DRow> = Table::new("d").with_shards(4);
        r.recover_from_dir(&dir).unwrap();
        assert_eq!(contents(&r), contents(&t));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Tentpole basics: with a hot-row budget set, `enforce_budget`
    /// spills least-recently-used shards to per-shard files, and the
    /// table keeps serving exact point reads, ordered scans, and cursor
    /// pagination over the hot/cold mix.
    #[test]
    fn spill_evicts_cold_shards_and_serves_reads_from_disk() {
        let dir = tmpdir("spill");
        let t: Table<DRow> = Table::new("d").with_shards(4);
        t.attach_wal(&dir, WalOptions { fsync: false, group_commit: false, leader: true })
            .unwrap();
        for i in 0..40 {
            t.insert(drow(i, &format!("v{i}")), 0).unwrap();
        }
        assert_eq!(t.enforce_budget().unwrap(), 0, "no budget, no eviction");
        t.set_memory_budget(10);
        assert_eq!(t.memory_budget(), 10);
        let evicted = t.enforce_budget().unwrap();
        assert!(evicted >= 1, "over budget: some shard must spill");
        let s = t.spill_stats();
        assert_eq!(s.shard_count, 4);
        assert_eq!(s.budget, 10);
        assert_eq!(s.cold_shards, evicted);
        assert_eq!(s.hot_rows + s.cold_rows, 40);
        assert!(s.hot_rows <= 10, "eviction reached the budget: {} hot", s.hot_rows);
        assert_eq!(s.evictions, evicted as u64);
        // a second pass has nothing left to do
        assert_eq!(t.enforce_budget().unwrap(), 0);
        // len / keys / point reads see through the hot/cold split
        assert_eq!(t.len(), 40);
        assert_eq!(t.keys(), (0..40).collect::<Vec<_>>());
        for i in 0..40 {
            assert_eq!(t.get(&i).unwrap().val, format!("v{i}"));
            assert!(t.contains(&i));
            assert_eq!(t.read(&i, |r| r.val.clone()).unwrap(), format!("v{i}"));
        }
        assert!(t.get(&999).is_none());
        assert!(t.spill_stats().disk_reads > 0, "cold point reads served from spill files");
        // ordered scans overlay the cold shards
        assert_eq!(contents(&t), (0..40).map(|i| (i, format!("v{i}"))).collect());
        // cursor pagination walks the same global order
        let mut paged = Vec::new();
        let mut cursor: Option<u64> = None;
        loop {
            let page = t.scan_page(cursor.as_ref(), 7);
            paged.extend(page.rows.into_iter().map(|r| r.id));
            match page.next_cursor {
                Some(c) => cursor = Some(c),
                None => break,
            }
        }
        assert_eq!(paged, (0..40).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Mutating a row in a cold shard faults the shard back in and
    /// marks it dirty; the next checkpoint is incremental — it rewrites
    /// exactly the dirty shard and skips the cold (clean) ones — and
    /// recovery still sees the whole table.
    #[test]
    fn spill_faults_in_on_mutation_and_checkpoints_incrementally() {
        let dir = tmpdir("fault");
        let t: Table<DRow> = Table::new("d").with_shards(4);
        t.attach_wal(&dir, WalOptions { fsync: false, group_commit: false, leader: true })
            .unwrap();
        for i in 0..40 {
            t.insert(drow(i, &format!("v{i}")), 0).unwrap();
        }
        let occupied = t
            .core
            .shards
            .iter()
            .filter(|s| !s.read().unwrap().rows.is_empty())
            .count();
        let ck = t.checkpoint().unwrap();
        assert_eq!(ck.rows, 40);
        assert_eq!(ck.shards_written, occupied, "first checkpoint writes every dirty shard");
        // Evict everything evictable, then find a key that actually
        // went cold (a 1-row shard may stay hot at budget 1).
        t.set_memory_budget(1);
        t.enforce_budget().unwrap();
        let s = t.spill_stats();
        assert!(s.cold_shards + 1 >= occupied, "nearly all shards evicted: {s:?}");
        assert!(s.hot_rows <= 1);
        let cold_key = (0..40u64)
            .find(|k| t.core.shards[t.shard_of(k)].read().unwrap().cold.is_some())
            .expect("some key lives in a cold shard");
        assert!(t.update(&cold_key, 1, |r| r.val = "mut".into()).is_some());
        let s2 = t.spill_stats();
        assert!(s2.fault_ins >= 1, "mutation faulted the cold shard in: {s2:?}");
        assert_eq!(s2.cold_shards, s.cold_shards - 1);
        assert_eq!(t.get(&cold_key).unwrap().val, "mut");
        // Incremental sweep: only the faulted (dirty) shard rewrites.
        let ck2 = t.checkpoint().unwrap();
        assert_eq!(ck2.rows, 40);
        assert_eq!(ck2.shards_written, 1, "only the mutated shard was rewritten");
        assert_eq!(ck2.shards_skipped, 3);
        // Recovery sees hot and cold rows alike.
        let r: Table<DRow> = Table::new("d").with_shards(4);
        let stats = r.recover_from_dir(&dir).unwrap();
        assert_eq!(stats.snapshot_rows, 40);
        assert_eq!(contents(&r), contents(&t));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// WAL compaction folds overwrite churn down to the last op per key
    /// and the folded log replays to the same state — with and without
    /// a checkpoint fence in front, and as a no-op when there is
    /// nothing to gain.
    #[test]
    fn compact_wal_folds_churn_and_preserves_recovery() {
        let dir = tmpdir("fold");
        let t: Table<DRow> = Table::new("d").with_shards(3);
        t.attach_wal(&dir, WalOptions { fsync: false, group_commit: false, leader: true })
            .unwrap();
        for round in 0..20u64 {
            for id in 0..5 {
                t.upsert(drow(id, &format!("r{round}")), round as i64);
            }
        }
        t.remove(&4, 99);
        let before = t.wal_stats().unwrap();
        assert!(before.records >= 100);
        let cs = t.compact_wal().unwrap();
        assert_eq!(cs.records_before, before.records);
        assert_eq!(cs.records_after, 1, "one folded commit, no fence yet");
        assert!(cs.ops_dropped >= 95, "churn dropped: {}", cs.ops_dropped);
        assert!(cs.bytes_after < cs.bytes_before);
        let r: Table<DRow> = Table::new("d");
        r.recover_from_dir(&dir).unwrap();
        assert_eq!(contents(&r), contents(&t));

        // After a checkpoint, compaction drops fenced records and
        // re-emits the fence barrier so recovery skips snapshot-covered
        // commits exactly as before.
        t.checkpoint().unwrap();
        for round in 0..10u64 {
            t.upsert(drow(1, &format!("s{round}")), 200 + round as i64);
        }
        let cs2 = t.compact_wal().unwrap();
        assert_eq!(cs2.records_after, 2, "fence barrier + one folded commit");
        assert!(cs2.ops_dropped >= 9);
        let r2: Table<DRow> = Table::new("d").with_shards(5);
        r2.recover_from_dir(&dir).unwrap();
        assert_eq!(contents(&r2), contents(&t));
        // Compacting the already-folded log gains nothing → no rewrite.
        let cs3 = t.compact_wal().unwrap();
        assert_eq!(cs3.records_before, 0, "no-gain fold leaves the log alone");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A checkpoint of a partially-spilled table round-trips through
    /// recovery even into a different shard layout, and the first
    /// checkpoint under the new layout removes the old layout's
    /// orphaned shard files.
    #[test]
    fn spilled_checkpoint_recovers_across_shard_layouts() {
        let dir = tmpdir("relayout");
        let t: Table<DRow> = Table::new("d").with_shards(8);
        t.attach_wal(&dir, WalOptions { fsync: false, group_commit: false, leader: true })
            .unwrap();
        for i in 0..60 {
            t.insert(drow(i, &format!("v{i}")), 0).unwrap();
        }
        t.set_memory_budget(20);
        t.enforce_budget().unwrap();
        assert!(t.spill_stats().cold_shards > 0);
        let ck = t.checkpoint().unwrap();
        assert_eq!(ck.rows, 60);
        assert!(ck.shards_skipped >= t.spill_stats().cold_shards, "cold shards not rewritten");
        // a post-checkpoint commit rides the WAL suffix
        t.upsert(drow(100, "x"), 5);
        // Recover into a 3-shard layout: per-shard snapshot rows are
        // re-placed by hash and the suffix replays on top.
        let r: Table<DRow> = Table::new("d").with_shards(3);
        let stats = r.recover_from_dir(&dir).unwrap();
        assert_eq!(stats.snapshot_rows, 60);
        assert_eq!(r.len(), 61);
        assert_eq!(contents(&r), contents(&t));
        // The new layout's first checkpoint rewrites its (re-placed,
        // dirty) shards and drops the 8-shard layout's extra files.
        r.attach_wal(&dir, WalOptions { fsync: false, group_commit: false, leader: true })
            .unwrap();
        let occupied = r
            .core
            .shards
            .iter()
            .filter(|s| !s.read().unwrap().rows.is_empty())
            .count();
        let ck2 = r.checkpoint().unwrap();
        assert_eq!(ck2.rows, 61);
        assert_eq!(ck2.shards_written, occupied);
        assert_eq!(ck2.shards_written + ck2.shards_skipped, 3);
        for i in 3..8 {
            assert!(
                !walmod::shard_snapshot_file(&dir, "d", i).exists(),
                "orphan shard file {i} removed"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Model property: a paged table under an aggressive budget (with
    /// eviction interleaved into the op stream) is observationally
    /// identical to the plain in-memory table — the spill layer must
    /// never change what a reader sees.
    #[test]
    fn prop_paged_table_matches_in_memory() {
        forall(20, |g| {
            let dir = tmpdir("pagedprop");
            let paged: Table<DRow> = Table::new("d").with_shards(g.usize(2, 8));
            paged
                .attach_wal(&dir, WalOptions { fsync: false, group_commit: g.bool(), leader: true })
                .unwrap();
            paged.set_memory_budget(g.usize(1, 10));
            let mut model: BTreeMap<u64, String> = BTreeMap::new();
            for step in 0..g.usize(20, 120) {
                let now = step as i64;
                let id = g.u64(0, 25);
                match g.usize(0, 5) {
                    0 => {
                        let val = g.ident(1..6);
                        paged.upsert(drow(id, &val), now);
                        model.insert(id, val);
                    }
                    1 => {
                        paged.remove(&id, now);
                        model.remove(&id);
                    }
                    2 => {
                        let val = g.ident(1..6);
                        let pm = paged.update(&id, now, |r| r.val = val.clone());
                        assert_eq!(pm.is_some(), model.contains_key(&id));
                        if model.contains_key(&id) {
                            model.insert(id, val);
                        }
                    }
                    3 => {
                        // reads must agree mid-stream, hot or cold
                        assert_eq!(paged.get(&id).map(|r| r.val), model.get(&id).cloned());
                        assert_eq!(paged.contains(&id), model.contains_key(&id));
                    }
                    _ => {
                        paged.enforce_budget().unwrap();
                        if g.chance(0.3) {
                            paged.checkpoint().unwrap();
                        }
                    }
                }
            }
            paged.enforce_budget().unwrap();
            let want: BTreeMap<u64, String> = model.clone();
            assert_eq!(contents(&paged), want, "paged scan == model");
            assert_eq!(paged.len(), model.len());
            assert_eq!(paged.keys(), model.keys().copied().collect::<Vec<_>>());
            let budget = paged.memory_budget();
            let s = paged.spill_stats();
            assert!(
                s.hot_rows <= budget || s.cold_shards + 1 >= s.shard_count,
                "budget enforced where possible: {s:?}"
            );
            // and the whole thing still recovers exactly
            let r: Table<DRow> = Table::new("d").with_shards(4);
            r.recover_from_dir(&dir).unwrap();
            assert_eq!(contents(&r), want);
            std::fs::remove_dir_all(&dir).ok();
        });
    }
}
