//! Typed tables with secondary indexes and history logs.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, RwLock};

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};

/// A row stored in a [`Table`]. The key must be stable for the lifetime of
/// the row (mutating a row's key is a delete + insert).
pub trait Row: Clone + Send + Sync + 'static {
    type Key: Ord + Clone + Send + Sync + 'static;
    fn key(&self) -> Self::Key;
}

/// Mutation kind recorded in history logs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Insert,
    Update,
    Delete,
}

/// Maintenance hook a secondary index registers with its table.
trait IndexMaint<V>: Send + Sync {
    fn on_insert(&self, row: &V);
    fn on_remove(&self, row: &V);
}

struct Inner<V: Row> {
    rows: BTreeMap<V::Key, V>,
    history: Option<Vec<(EpochMs, Op, V)>>,
}

/// A typed, thread-safe, ordered table.
pub struct Table<V: Row> {
    name: &'static str,
    inner: RwLock<Inner<V>>,
    indexes: RwLock<Vec<Arc<dyn IndexMaint<V>>>>,
}

impl<V: Row> Table<V> {
    pub fn new(name: &'static str) -> Self {
        Table {
            name,
            inner: RwLock::new(Inner { rows: BTreeMap::new(), history: None }),
            indexes: RwLock::new(Vec::new()),
        }
    }

    /// Enable the history log (paper §3.6 "storing of deleted rows in
    /// historical tables").
    pub fn with_history(self) -> Self {
        self.inner.write().unwrap().history = Some(Vec::new());
        self
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Attach a secondary index. Must be called before rows exist (indexes
    /// do not back-fill); enforced with an error otherwise.
    pub fn add_index<IK>(&self, index: &Index<V, IK>) -> Result<()>
    where
        IK: Ord + Clone + Send + Sync + 'static,
    {
        if !self.inner.read().unwrap().rows.is_empty() {
            return Err(RucioError::DatabaseError(format!(
                "table {}: add_index on non-empty table",
                self.name
            )));
        }
        self.indexes.write().unwrap().push(index.maint.clone());
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a new row; errors on duplicate key.
    pub fn insert(&self, row: V, now: EpochMs) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        let key = row.key();
        if inner.rows.contains_key(&key) {
            return Err(RucioError::Duplicate(format!("table {}: duplicate key", self.name)));
        }
        for idx in self.indexes.read().unwrap().iter() {
            idx.on_insert(&row);
        }
        if let Some(h) = &mut inner.history {
            h.push((now, Op::Insert, row.clone()));
        }
        inner.rows.insert(key, row);
        Ok(())
    }

    /// Insert or replace.
    pub fn upsert(&self, row: V, now: EpochMs) {
        let mut inner = self.inner.write().unwrap();
        let key = row.key();
        let indexes = self.indexes.read().unwrap();
        if let Some(old) = inner.rows.get(&key) {
            for idx in indexes.iter() {
                idx.on_remove(old);
            }
        }
        for idx in indexes.iter() {
            idx.on_insert(&row);
        }
        if let Some(h) = &mut inner.history {
            h.push((now, Op::Update, row.clone()));
        }
        inner.rows.insert(key, row);
    }

    pub fn get(&self, key: &V::Key) -> Option<V> {
        self.inner.read().unwrap().rows.get(key).cloned()
    }

    pub fn contains(&self, key: &V::Key) -> bool {
        self.inner.read().unwrap().rows.contains_key(key)
    }

    /// In-place mutation through a closure; index entries are refreshed.
    /// Returns the updated row, or `None` if absent.
    pub fn update<F: FnOnce(&mut V)>(&self, key: &V::Key, now: EpochMs, f: F) -> Option<V> {
        let mut inner = self.inner.write().unwrap();
        let row = inner.rows.get(key)?.clone();
        let indexes = self.indexes.read().unwrap();
        for idx in indexes.iter() {
            idx.on_remove(&row);
        }
        let mut new_row = row;
        f(&mut new_row);
        debug_assert!(new_row.key() == *key, "update must not change the primary key");
        for idx in indexes.iter() {
            idx.on_insert(&new_row);
        }
        if let Some(h) = &mut inner.history {
            h.push((now, Op::Update, new_row.clone()));
        }
        inner.rows.insert(key.clone(), new_row.clone());
        Some(new_row)
    }

    pub fn remove(&self, key: &V::Key, now: EpochMs) -> Option<V> {
        let mut inner = self.inner.write().unwrap();
        let row = inner.rows.remove(key)?;
        for idx in self.indexes.read().unwrap().iter() {
            idx.on_remove(&row);
        }
        if let Some(h) = &mut inner.history {
            h.push((now, Op::Delete, row.clone()));
        }
        Some(row)
    }

    /// Snapshot scan with a filter (clones matching rows).
    pub fn scan<F: FnMut(&V) -> bool>(&self, mut pred: F) -> Vec<V> {
        self.inner
            .read()
            .unwrap()
            .rows
            .values()
            .filter(|v| pred(v))
            .cloned()
            .collect()
    }

    /// Scan at most `limit` matching rows (the daemon "read a batch" path —
    /// keeps reaper/conveyor scans O(batch) when combined with indexes).
    pub fn scan_limit<F: FnMut(&V) -> bool>(&self, limit: usize, mut pred: F) -> Vec<V> {
        let inner = self.inner.read().unwrap();
        let mut out = Vec::new();
        for v in inner.rows.values() {
            if pred(v) {
                out.push(v.clone());
                if out.len() >= limit {
                    break;
                }
            }
        }
        out
    }

    /// Fold over all rows without cloning.
    pub fn fold<A, F: FnMut(A, &V) -> A>(&self, init: A, mut f: F) -> A {
        let inner = self.inner.read().unwrap();
        let mut acc = init;
        for v in inner.rows.values() {
            acc = f(acc, v);
        }
        acc
    }

    /// Visit every row (no clone); used by reports.
    pub fn for_each<F: FnMut(&V)>(&self, mut f: F) {
        let inner = self.inner.read().unwrap();
        for v in inner.rows.values() {
            f(v);
        }
    }

    /// All keys (cheap-ish snapshot for iteration patterns).
    pub fn keys(&self) -> Vec<V::Key> {
        self.inner.read().unwrap().rows.keys().cloned().collect()
    }

    /// History snapshot (empty if history is disabled).
    pub fn history(&self) -> Vec<(EpochMs, Op, V)> {
        self.inner.read().unwrap().history.clone().unwrap_or_default()
    }
}

struct IndexInner<V: Row, IK: Ord> {
    map: BTreeMap<IK, BTreeSet<V::Key>>,
}

struct IndexMaintImpl<V: Row, IK: Ord> {
    extract: Box<dyn Fn(&V) -> Option<IK> + Send + Sync>,
    inner: RwLock<IndexInner<V, IK>>,
}

impl<V: Row, IK: Ord + Clone + Send + Sync + 'static> IndexMaint<V> for IndexMaintImpl<V, IK> {
    fn on_insert(&self, row: &V) {
        if let Some(ik) = (self.extract)(row) {
            self.inner
                .write()
                .unwrap()
                .map
                .entry(ik)
                .or_default()
                .insert(row.key());
        }
    }

    fn on_remove(&self, row: &V) {
        if let Some(ik) = (self.extract)(row) {
            let mut inner = self.inner.write().unwrap();
            if let Some(set) = inner.map.get_mut(&ik) {
                set.remove(&row.key());
                if set.is_empty() {
                    inner.map.remove(&ik);
                }
            }
        }
    }
}

/// A secondary index over a [`Table`]: maps an extracted key to the set of
/// primary keys. Rows whose extractor returns `None` are simply not indexed
/// (partial index — e.g. "only STUCK rules", the hot daemon queues).
pub struct Index<V: Row, IK: Ord + Clone + Send + Sync + 'static> {
    maint: Arc<IndexMaintImpl<V, IK>>,
}

impl<V: Row, IK: Ord + Clone + Send + Sync + 'static> Index<V, IK> {
    pub fn new<F: Fn(&V) -> Option<IK> + Send + Sync + 'static>(extract: F) -> Self {
        Index {
            maint: Arc::new(IndexMaintImpl {
                extract: Box::new(extract),
                inner: RwLock::new(IndexInner { map: BTreeMap::new() }),
            }),
        }
    }

    /// Primary keys with exactly this index key.
    pub fn get(&self, ik: &IK) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.iter().cloned().collect())
            .unwrap_or_default()
    }

    /// Up to `limit` primary keys with this index key.
    pub fn get_limit(&self, ik: &IK, limit: usize) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.iter().take(limit).cloned().collect())
            .unwrap_or_default()
    }

    /// Primary keys for index keys in `[lo, hi)` — range scans (e.g.
    /// "expiration timestamp before now", the reaper/judge work queues).
    pub fn range(&self, lo: &IK, hi: &IK) -> Vec<V::Key> {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .range(lo.clone()..hi.clone())
            .flat_map(|(_, s)| s.iter().cloned())
            .collect()
    }

    /// Up to `limit` primary keys for index keys in `[lo, hi)`, smallest
    /// index keys first (FIFO work queues keyed by timestamp).
    pub fn range_limit(&self, lo: &IK, hi: &IK, limit: usize) -> Vec<V::Key> {
        let inner = self.maint.inner.read().unwrap();
        let mut out = Vec::new();
        for (_, s) in inner.map.range(lo.clone()..hi.clone()) {
            for k in s {
                out.push(k.clone());
                if out.len() >= limit {
                    return out;
                }
            }
        }
        out
    }

    pub fn count(&self, ik: &IK) -> usize {
        self.maint
            .inner
            .read()
            .unwrap()
            .map
            .get(ik)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Number of distinct index keys.
    pub fn cardinality(&self) -> usize {
        self.maint.inner.read().unwrap().map.len()
    }

    /// Total indexed rows.
    pub fn len(&self) -> usize {
        self.maint.inner.read().unwrap().map.values().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Distinct index keys (snapshot).
    pub fn index_keys(&self) -> Vec<IK> {
        self.maint.inner.read().unwrap().map.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::forall;

    #[derive(Clone, Debug, PartialEq)]
    struct Item {
        id: u64,
        state: &'static str,
        rse: String,
    }

    impl Row for Item {
        type Key = u64;
        fn key(&self) -> u64 {
            self.id
        }
    }

    fn item(id: u64, state: &'static str, rse: &str) -> Item {
        Item { id, state, rse: rse.to_string() }
    }

    #[test]
    fn crud_basics() {
        let t: Table<Item> = Table::new("items");
        t.insert(item(1, "new", "A"), 0).unwrap();
        t.insert(item(2, "new", "B"), 0).unwrap();
        assert_eq!(t.len(), 2);
        assert!(t.insert(item(1, "dup", "A"), 0).is_err());
        assert_eq!(t.get(&1).unwrap().state, "new");
        t.update(&1, 1, |r| r.state = "done");
        assert_eq!(t.get(&1).unwrap().state, "done");
        assert_eq!(t.remove(&2, 2).unwrap().rse, "B");
        assert_eq!(t.len(), 1);
        assert!(t.remove(&2, 3).is_none());
    }

    #[test]
    fn index_tracks_mutations() {
        let t: Table<Item> = Table::new("items");
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();

        t.insert(item(1, "new", "A"), 0).unwrap();
        t.insert(item(2, "new", "B"), 0).unwrap();
        t.insert(item(3, "done", "A"), 0).unwrap();
        assert_eq!(by_state.get(&"new"), vec![1, 2]);
        assert_eq!(by_state.count(&"done"), 1);

        t.update(&1, 1, |r| r.state = "done");
        assert_eq!(by_state.get(&"new"), vec![2]);
        assert_eq!(by_state.get(&"done"), vec![1, 3]);

        t.remove(&3, 2);
        assert_eq!(by_state.get(&"done"), vec![1]);
    }

    #[test]
    fn partial_index_skips_none() {
        let t: Table<Item> = Table::new("items");
        let stuck: Index<Item, u64> =
            Index::new(|r: &Item| if r.state == "stuck" { Some(r.id) } else { None });
        t.add_index(&stuck).unwrap();
        t.insert(item(1, "new", "A"), 0).unwrap();
        t.insert(item(2, "stuck", "A"), 0).unwrap();
        assert_eq!(stuck.len(), 1);
        t.update(&1, 1, |r| r.state = "stuck");
        assert_eq!(stuck.len(), 2);
        t.update(&2, 2, |r| r.state = "done");
        assert_eq!(stuck.len(), 1);
    }

    #[test]
    fn range_queries_work() {
        let t: Table<Item> = Table::new("items");
        let by_id_band: Index<Item, u64> = Index::new(|r: &Item| Some(r.id * 10));
        t.add_index(&by_id_band).unwrap();
        for i in 1..=10 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        let keys = by_id_band.range(&20, &51); // ids 2..=5
        assert_eq!(keys, vec![2, 3, 4, 5]);
        let limited = by_id_band.range_limit(&0, &1000, 3);
        assert_eq!(limited.len(), 3);
        assert_eq!(limited, vec![1, 2, 3]); // smallest index keys first
    }

    #[test]
    fn add_index_on_nonempty_rejected() {
        let t: Table<Item> = Table::new("items");
        t.insert(item(1, "new", "A"), 0).unwrap();
        let idx: Index<Item, u64> = Index::new(|r: &Item| Some(r.id));
        assert!(t.add_index(&idx).is_err());
    }

    #[test]
    fn history_records_ops() {
        let t: Table<Item> = Table::new("items").with_history();
        t.insert(item(1, "new", "A"), 10).unwrap();
        t.update(&1, 20, |r| r.state = "done");
        t.remove(&1, 30);
        let h = t.history();
        assert_eq!(h.len(), 3);
        assert_eq!(h[0].1, Op::Insert);
        assert_eq!(h[1].1, Op::Update);
        assert_eq!(h[2].1, Op::Delete);
        assert_eq!(h[2].0, 30);
    }

    #[test]
    fn upsert_replaces_and_reindexes() {
        let t: Table<Item> = Table::new("items");
        let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
        t.add_index(&by_state).unwrap();
        t.upsert(item(1, "new", "A"), 0);
        t.upsert(item(1, "done", "B"), 1);
        assert_eq!(t.len(), 1);
        assert_eq!(by_state.count(&"new"), 0);
        assert_eq!(by_state.count(&"done"), 1);
    }

    #[test]
    fn scan_limit_stops_early() {
        let t: Table<Item> = Table::new("items");
        for i in 0..100 {
            t.insert(item(i, "new", "A"), 0).unwrap();
        }
        assert_eq!(t.scan_limit(7, |_| true).len(), 7);
        assert_eq!(t.scan(|r| r.id < 5).len(), 5);
    }

    #[test]
    fn prop_index_consistent_under_random_ops() {
        forall(60, |g| {
            let t: Table<Item> = Table::new("items");
            let states = ["a", "b", "c"];
            let by_state: Index<Item, &'static str> = Index::new(|r: &Item| Some(r.state));
            t.add_index(&by_state).unwrap();
            let mut live = std::collections::BTreeMap::new();
            for step in 0..g.usize(10, 200) {
                let id = g.u64(0, 30);
                match g.usize(0, 3) {
                    0 => {
                        let st = *g.pick(&states);
                        if t.insert(item(id, st, "X"), step as i64).is_ok() {
                            live.insert(id, st);
                        }
                    }
                    1 => {
                        let st = *g.pick(&states);
                        if t.update(&id, step as i64, |r| r.state = st).is_some() {
                            live.insert(id, st);
                        }
                    }
                    _ => {
                        t.remove(&id, step as i64);
                        live.remove(&id);
                    }
                }
            }
            // Model equivalence: index contents == reference map.
            for st in states {
                let mut expect: Vec<u64> = live
                    .iter()
                    .filter(|(_, v)| **v == st)
                    .map(|(k, _)| *k)
                    .collect();
                expect.sort();
                assert_eq!(by_state.get(&st), expect, "state {st}");
            }
            assert_eq!(t.len(), live.len());
        });
    }

    #[test]
    fn concurrent_readers_and_writers() {
        use std::sync::Arc;
        let t: Arc<Table<Item>> = Arc::new(Table::new("items"));
        let mut handles = vec![];
        for w in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let id = w * 1000 + i;
                    t.insert(item(id, "new", "A"), 0).unwrap();
                    if i % 3 == 0 {
                        t.update(&id, 1, |r| r.state = "done");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 2000);
        let done = t.scan(|r| r.state == "done");
        assert_eq!(done.len(), 4 * 167);
    }
}
