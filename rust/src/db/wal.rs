//! Write-ahead log + snapshot persistence for [`crate::db::Table`]
//! (paper §3.6: the catalog is grounded in a transactional persistence
//! layer — restart-from-disk is a routine operation, not data loss).
//!
//! ## On-disk format
//!
//! Both WAL and snapshot files are sequences of *frames*:
//!
//! ```text
//! [payload length: u32 LE][SHA-256(payload): 32 bytes][payload: JSON]
//! ```
//!
//! The checksum (reusing [`crate::common::checksum::sha256`]) makes a
//! torn tail — a frame cut short by a crash mid-write — detectable: the
//! reader stops at the first frame whose length runs past the file end
//! or whose digest mismatches, discards everything from there on, and
//! reports `torn = true`. A frame is the atomicity unit, so a commit
//! (which is one frame) is never half-applied on recovery.
//!
//! ## Record payloads
//!
//! * commit — `{"k":"c","seq":N,"ops":[{"o":"u","row":…}|{"o":"r","key":…}]}`
//!   One frame per table commit under group commit (the default): a bulk
//!   batch of thousands of mutations costs one write (and at most one
//!   fsync). With `group_commit = false` every op gets its own frame and
//!   its own fsync — the ablation baseline of `benches/abl_wal_commit`.
//! * barrier — `{"k":"b","seq":N}` — the snapshot fence written by
//!   [`crate::db::Table::checkpoint`]: a snapshot with `ckpt = N` covers
//!   exactly the records with `seq <= N`, so recovery replays only the
//!   suffix `seq > N`.
//!
//! Snapshot files are written to a temp file and atomically renamed, so
//! a crash mid-checkpoint leaves either the old or the new snapshot —
//! never a torn one. After the rename the WAL is truncated back to a
//! single barrier frame; a crash between the two steps is benign because
//! the seq fence makes replay of pre-snapshot records a no-op.
//!
//! ## Crash model
//!
//! Atomicity is **per table commit**: one frame is applied whole or not
//! at all (under `group_commit = false`, the unit shrinks to one op).
//! There is no cross-table transaction marker — a catalog operation
//! that commits to several tables (e.g. a rule touching rules, locks,
//! replicas, requests) appends to each table's log independently, so a
//! torn tail landing *mid-operation* can recover some tables one commit
//! ahead of others. The simulator's `ProcessCrash` fires between driver
//! steps (operation boundaries), where per-table recovery implies full
//! cross-table consistency; power-loss-grade tearing mid-operation is
//! out of scope and would need a global commit epoch.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::common::checksum;
use crate::common::error::{Result, RucioError};
use crate::jsonx::Json;

/// Bytes of frame overhead before the payload (length + SHA-256).
const FRAME_HEADER: usize = 4 + 32;

/// A row type that can live in a durable table: JSON encodings for the
/// row and for its primary key (the `Remove` side of the log). All
/// catalog row types implement this in `core::persist`.
pub trait Durable: crate::db::Row {
    fn row_to_json(&self) -> Json;
    fn row_from_json(j: &Json) -> Result<Self>;
    fn key_to_json(key: &Self::Key) -> Json;
    fn key_from_json(j: &Json) -> Result<Self::Key>;
}

/// Durability knobs, from config `[db] fsync` / `[db] group_commit`.
#[derive(Debug, Clone, Copy)]
pub struct WalOptions {
    /// `fsync` after every commit frame (power-loss durability). Off by
    /// default: the sim's crash model is process death, where the OS
    /// page cache survives.
    pub fsync: bool,
    /// One frame per table commit (default) vs one frame (and fsync)
    /// per op — the group-commit ablation switch.
    pub group_commit: bool,
}

impl Default for WalOptions {
    fn default() -> Self {
        WalOptions { fsync: false, group_commit: true }
    }
}

/// Live WAL shape, for monitoring (`analytics::reports::wal_stats`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WalStats {
    /// Bytes currently in the log file.
    pub bytes: u64,
    /// Frames currently in the log file (incl. barriers).
    pub records: u64,
    /// Commit frames appended since the last barrier.
    pub records_since_checkpoint: u64,
    /// Seq of the most recent barrier (0 = never checkpointed).
    pub last_checkpoint_seq: u64,
    /// Next record seq to be allocated.
    pub next_seq: u64,
}

/// Outcome of one [`crate::db::Table::checkpoint`].
#[derive(Debug, Clone, Default)]
pub struct CheckpointStats {
    /// Rows written into the snapshot.
    pub rows: usize,
    /// Snapshot file size in bytes.
    pub snapshot_bytes: u64,
    /// The barrier seq fencing this snapshot.
    pub seq: u64,
}

/// Outcome of one [`crate::db::Table::recover`].
#[derive(Debug, Clone, Default)]
pub struct RecoverStats {
    /// Rows loaded from the snapshot.
    pub snapshot_rows: usize,
    /// The snapshot's barrier seq (0 = no snapshot found).
    pub snapshot_seq: u64,
    /// Commit frames replayed from the WAL suffix.
    pub replayed_records: u64,
    /// Individual ops applied during replay.
    pub replayed_ops: u64,
    /// True when a torn (truncated/corrupt) tail was detected and
    /// discarded — the checksummed frame boundary guarantees the
    /// discarded record was never partially applied.
    pub torn_tail: bool,
}

/// Object-safe persistence handle a durable [`crate::db::Table`] exposes
/// so [`crate::db::Registry::checkpoint_all`] can drive snapshots
/// without knowing row types.
pub trait TablePersist: Send + Sync {
    fn table_name(&self) -> &'static str;
    fn checkpoint(&self) -> Result<CheckpointStats>;
    fn wal_stats(&self) -> Option<WalStats>;
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

fn frame_into(out: &mut Vec<u8>, payload: &Json) {
    let text = payload.to_string();
    let bytes = text.as_bytes();
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&checksum::sha256(bytes));
    out.extend_from_slice(bytes);
}

fn frame(payload: &Json) -> Vec<u8> {
    let mut out = Vec::new();
    frame_into(&mut out, payload);
    out
}

/// One decoded WAL frame.
#[derive(Debug, Clone)]
pub struct WalRecord {
    /// Record sequence number (`seq` field of the payload).
    pub seq: u64,
    pub payload: Json,
    /// Byte offset just past this frame — crash-point granularity for
    /// the torn-tail property tests.
    pub end_offset: u64,
}

/// Result of scanning a framed file leniently (WAL semantics: a torn
/// tail is expected after a crash and simply discarded).
#[derive(Debug, Clone, Default)]
pub struct WalReadResult {
    pub records: Vec<WalRecord>,
    /// Length of the valid prefix in bytes.
    pub valid_bytes: u64,
    /// True when trailing bytes after the valid prefix were discarded.
    pub torn: bool,
}

/// Read every complete, checksum-valid frame from `path`. A missing file
/// reads as empty. Stops (and flags `torn`) at the first incomplete or
/// corrupt frame.
pub fn read_records(path: &Path) -> Result<WalReadResult> {
    if !path.exists() {
        return Ok(WalReadResult::default());
    }
    let data = std::fs::read(path)?;
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        if pos == data.len() {
            break;
        }
        if pos + FRAME_HEADER > data.len() {
            break; // torn header
        }
        let mut len_bytes = [0u8; 4];
        len_bytes.copy_from_slice(&data[pos..pos + 4]);
        let len = u32::from_le_bytes(len_bytes) as usize;
        if pos + FRAME_HEADER + len > data.len() {
            break; // torn payload
        }
        let digest = &data[pos + 4..pos + FRAME_HEADER];
        let payload = &data[pos + FRAME_HEADER..pos + FRAME_HEADER + len];
        if &checksum::sha256(payload)[..] != digest {
            break; // corrupt frame: treat like a torn tail
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(json) = Json::parse(text) else { break };
        pos += FRAME_HEADER + len;
        let seq = json.opt_u64("seq").unwrap_or(0);
        records.push(WalRecord { seq, payload: json, end_offset: pos as u64 });
    }
    Ok(WalReadResult { records, valid_bytes: pos as u64, torn: pos < data.len() })
}

/// Read a framed file strictly (snapshot semantics: snapshots are
/// written atomically, so a torn snapshot is corruption, not a crash
/// artifact). Returns the payloads in order.
pub fn read_frames(path: &Path) -> Result<Vec<Json>> {
    let scan = read_records(path)?;
    if scan.torn {
        return Err(RucioError::DatabaseError(format!(
            "{}: torn or corrupt frame at byte {}",
            path.display(),
            scan.valid_bytes
        )));
    }
    Ok(scan.records.into_iter().map(|r| r.payload).collect())
}

/// Write `frames` to `path` atomically: temp file, optional fsync, then
/// rename. Returns the file size. Used for snapshots and the manifest.
pub fn write_frames_atomic(path: &Path, frames: &[Json], fsync: bool) -> Result<u64> {
    let mut buf = Vec::new();
    for f in frames {
        frame_into(&mut buf, f);
    }
    let tmp = tmp_path(path);
    {
        let mut file = File::create(&tmp)?;
        file.write_all(&buf)?;
        if fsync {
            file.sync_data()?;
        }
    }
    std::fs::rename(&tmp, path)?;
    Ok(buf.len() as u64)
}

/// Snapshot file for table `name` under the durability dir.
pub fn snapshot_file(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.snap"))
}

/// WAL file for table `name` under the durability dir.
pub fn wal_file(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.wal"))
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

// ---------------------------------------------------------------------
// the log
// ---------------------------------------------------------------------

struct WalInner {
    file: File,
    bytes: u64,
    records: u64,
    next_seq: u64,
    last_barrier_seq: u64,
    records_since_barrier: u64,
}

/// A per-table append-only write-ahead log. All appends serialize on an
/// internal mutex; tables call in while holding their shard locks, so
/// WAL order matches commit order per key.
pub struct Wal {
    path: PathBuf,
    opts: WalOptions,
    inner: Mutex<WalInner>,
}

impl Wal {
    /// Open (or create) the log at `path`, scanning existing frames to
    /// restore counters. A torn tail is truncated away so new appends
    /// always follow a valid frame.
    pub fn open(path: &Path, opts: WalOptions) -> Result<Wal> {
        let scan = read_records(path)?;
        if scan.torn {
            let f = OpenOptions::new().write(true).create(true).open(path)?;
            f.set_len(scan.valid_bytes)?;
        }
        let mut next_seq = 1u64;
        let mut last_barrier_seq = 0u64;
        let mut records_since_barrier = 0u64;
        for r in &scan.records {
            next_seq = next_seq.max(r.seq + 1);
            if r.payload.opt_str("k") == Some("b") {
                last_barrier_seq = r.seq;
                records_since_barrier = 0;
            } else {
                records_since_barrier += 1;
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Wal {
            path: path.to_path_buf(),
            opts,
            inner: Mutex::new(WalInner {
                file,
                bytes: scan.valid_bytes,
                records: scan.records.len() as u64,
                next_seq,
                last_barrier_seq,
                records_since_barrier,
            }),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn fsync_enabled(&self) -> bool {
        self.opts.fsync
    }

    /// Append one already-framed record. On any IO error the file is
    /// rolled back to the last known-good frame boundary, so a partial
    /// append can never poison the frames that follow it — only this
    /// one record is lost, not everything appended after it. Counters
    /// (including the seq) advance only on success.
    fn append_frame(inner: &mut WalInner, buf: &[u8], fsync: bool) -> Result<()> {
        let mut res = inner.file.write_all(buf).map_err(RucioError::from);
        if res.is_ok() && fsync {
            res = inner.file.sync_data().map_err(RucioError::from);
        }
        match res {
            Ok(()) => {
                inner.bytes += buf.len() as u64;
                Ok(())
            }
            Err(e) => {
                let _ = inner.file.set_len(inner.bytes);
                Err(e)
            }
        }
    }

    /// Append one table commit. Under group commit the whole op list is
    /// one frame (one write, at most one fsync); otherwise each op is
    /// its own frame with its own fsync — the per-record baseline.
    pub fn commit(&self, ops: Vec<Json>) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        let mut inner = self.inner.lock().unwrap();
        if self.opts.group_commit {
            let seq = inner.next_seq;
            let payload =
                Json::obj().with("k", "c").with("seq", seq).with("ops", Json::Arr(ops));
            let buf = frame(&payload);
            Self::append_frame(&mut inner, &buf, self.opts.fsync)?;
            inner.next_seq += 1;
            inner.records += 1;
            inner.records_since_barrier += 1;
        } else {
            for op in ops {
                let seq = inner.next_seq;
                let payload =
                    Json::obj().with("k", "c").with("seq", seq).with("ops", Json::Arr(vec![op]));
                let buf = frame(&payload);
                Self::append_frame(&mut inner, &buf, self.opts.fsync)?;
                inner.next_seq += 1;
                inner.records += 1;
                inner.records_since_barrier += 1;
            }
        }
        Ok(())
    }

    /// Append a snapshot barrier and return its seq. The caller must
    /// hold the table's shard locks so the fence position is exact.
    pub fn barrier(&self) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        let buf = frame(&Json::obj().with("k", "b").with("seq", seq));
        Self::append_frame(&mut inner, &buf, self.opts.fsync)?;
        inner.next_seq += 1;
        inner.records += 1;
        inner.last_barrier_seq = seq;
        inner.records_since_barrier = 0;
        Ok(seq)
    }

    /// Rewrite the log to contain only the barrier frame `seq` — called
    /// after the snapshot fenced by that barrier has been renamed into
    /// place. Atomic (temp file + rename); the append handle is reopened
    /// on the new file.
    pub fn truncate_to_barrier(&self, seq: u64) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let buf = frame(&Json::obj().with("k", "b").with("seq", seq));
        let tmp = tmp_path(&self.path);
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&buf)?;
            if self.opts.fsync {
                f.sync_data()?;
            }
        }
        std::fs::rename(&tmp, &self.path)?;
        inner.file = OpenOptions::new().append(true).open(&self.path)?;
        inner.bytes = buf.len() as u64;
        inner.records = 1;
        inner.last_barrier_seq = seq;
        inner.records_since_barrier = 0;
        Ok(())
    }

    pub fn stats(&self) -> WalStats {
        let inner = self.inner.lock().unwrap();
        WalStats {
            bytes: inner.bytes,
            records: inner.records,
            records_since_checkpoint: inner.records_since_barrier,
            last_checkpoint_seq: inner.last_barrier_seq,
            next_seq: inner.next_seq,
        }
    }
}

/// Replay helper shared by table recovery and tests: the `(key, op)`
/// view of one commit frame's ops, decoded through a [`Durable`] type.
pub fn decode_ops<V: Durable>(record: &Json) -> Result<Vec<ReplayOp<V>>> {
    let ops = record
        .get("ops")
        .and_then(Json::as_arr)
        .ok_or_else(|| RucioError::DatabaseError("wal commit frame without ops".into()))?;
    let mut out = Vec::with_capacity(ops.len());
    for op in ops {
        match op.opt_str("o") {
            Some("u") => {
                let row = op
                    .get("row")
                    .ok_or_else(|| RucioError::DatabaseError("wal put op without row".into()))?;
                out.push(ReplayOp::Put(V::row_from_json(row)?));
            }
            Some("r") => {
                let key = op
                    .get("key")
                    .ok_or_else(|| RucioError::DatabaseError("wal del op without key".into()))?;
                out.push(ReplayOp::Del(V::key_from_json(key)?));
            }
            other => {
                return Err(RucioError::DatabaseError(format!(
                    "unknown wal op kind {other:?}"
                )));
            }
        }
    }
    Ok(out)
}

/// One decoded replay op.
pub enum ReplayOp<V: Durable> {
    /// Insert-or-replace (covers live inserts, upserts, and updates).
    Put(V),
    /// Remove by key (missing keys are no-ops on replay).
    Del(V::Key),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        static N: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let i = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!("rucio-wal-{}-{name}-{i}", std::process::id()))
    }

    fn op(i: u64) -> Json {
        Json::obj().with("o", "u").with("row", Json::obj().with("id", i))
    }

    #[test]
    fn commit_read_round_trip() {
        let path = tmp("rt");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1), op(2)]).unwrap();
        wal.commit(vec![op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert!(!scan.torn);
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[0].seq, 1);
        assert_eq!(scan.records[1].seq, 2);
        let ops = scan.records[0].payload.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops.len(), 2, "group commit: one frame for the batch");
        let stats = wal.stats();
        assert_eq!(stats.records, 2);
        assert_eq!(stats.records_since_checkpoint, 2);
        assert_eq!(stats.next_seq, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn per_record_mode_writes_one_frame_per_op() {
        let path = tmp("per");
        let wal =
            Wal::open(&path, WalOptions { fsync: false, group_commit: false }).unwrap();
        wal.commit(vec![op(1), op(2), op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert_eq!(scan.records.len(), 3);
        assert_eq!(scan.records[2].seq, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_detected_and_dropped_on_reopen() {
        let path = tmp("torn");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1)]).unwrap();
        wal.commit(vec![op(2)]).unwrap();
        let full = std::fs::metadata(&path).unwrap().len();
        drop(wal);
        // cut into the final frame
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 5).unwrap();
        drop(f);
        let scan = read_records(&path).unwrap();
        assert!(scan.torn);
        assert_eq!(scan.records.len(), 1, "only the complete frame survives");
        // reopen truncates the garbage and continues the seq
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), scan.valid_bytes);
        wal.commit(vec![op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert!(!scan.torn);
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[1].seq, 2, "seq continues past the valid prefix");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_byte_invalidates_the_frame() {
        let path = tmp("corrupt");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1)]).unwrap();
        wal.commit(vec![op(2)]).unwrap();
        drop(wal);
        let mut data = std::fs::read(&path).unwrap();
        let last = data.len() - 3;
        data[last] ^= 0xFF; // flip a payload byte inside the second frame
        std::fs::write(&path, &data).unwrap();
        let scan = read_records(&path).unwrap();
        assert!(scan.torn, "checksum mismatch reads as a torn tail");
        assert_eq!(scan.records.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn barrier_and_truncate_fence_the_log() {
        let path = tmp("barrier");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1)]).unwrap();
        let seq = wal.barrier().unwrap();
        assert_eq!(seq, 2);
        wal.commit(vec![op(2)]).unwrap();
        let stats = wal.stats();
        assert_eq!(stats.last_checkpoint_seq, 2);
        assert_eq!(stats.records_since_checkpoint, 1);
        wal.truncate_to_barrier(seq).unwrap();
        let scan = read_records(&path).unwrap();
        assert_eq!(scan.records.len(), 1);
        assert_eq!(scan.records[0].payload.opt_str("k"), Some("b"));
        // appends continue with the pre-truncation seq counter
        wal.commit(vec![op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert_eq!(scan.records[1].seq, 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn snapshot_frames_round_trip_and_reject_corruption() {
        let path = tmp("snap");
        let frames =
            vec![Json::obj().with("k", "snap").with("ckpt", 7u64), Json::obj().with("i", 0)];
        let bytes = write_frames_atomic(&path, &frames, false).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let back = read_frames(&path).unwrap();
        assert_eq!(back, frames);
        // a torn snapshot is an error, not a silent partial read
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(bytes - 2).unwrap();
        drop(f);
        assert!(read_frames(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_reads_empty() {
        let path = tmp("missing");
        let scan = read_records(&path).unwrap();
        assert!(scan.records.is_empty() && !scan.torn && scan.valid_bytes == 0);
    }
}
