//! Write-ahead log + snapshot persistence for [`crate::db::Table`]
//! (paper §3.6: the catalog is grounded in a transactional persistence
//! layer — restart-from-disk is a routine operation, not data loss).
//!
//! ## On-disk format
//!
//! Both WAL and snapshot files are sequences of *frames*:
//!
//! ```text
//! [payload length: u32 LE][SHA-256(payload): 32 bytes][payload: JSON]
//! ```
//!
//! The checksum (reusing [`crate::common::checksum::sha256`]) makes a
//! torn tail — a frame cut short by a crash mid-write — detectable: the
//! reader stops at the first frame whose length runs past the file end
//! or whose digest mismatches, discards everything from there on, and
//! reports `torn = true`. A frame is the atomicity unit, so a commit
//! (which is one frame) is never half-applied on recovery.
//!
//! ## Record payloads
//!
//! * commit — `{"k":"c","seq":N,"ops":[{"o":"u","row":…}|{"o":"r","key":…}]}`
//!   One frame per table commit under group commit (the default): a bulk
//!   batch of thousands of mutations costs one write (and at most one
//!   fsync). With `group_commit = false` every op gets its own frame and
//!   its own fsync — the ablation baseline of `benches/abl_wal_commit`.
//! * barrier — `{"k":"b","seq":N}` — the snapshot fence written by
//!   [`crate::db::Table::checkpoint`]: a snapshot with `ckpt = N` covers
//!   exactly the records with `seq <= N`, so recovery replays only the
//!   suffix `seq > N`.
//!
//! Snapshot files are written to a temp file and atomically renamed, so
//! a crash mid-checkpoint leaves either the old or the new snapshot —
//! never a torn one. After the rename the WAL is truncated down to the
//! barrier frame plus any records committed after the fence (writers
//! run concurrently with the snapshot's file IO); a crash between the
//! two steps is benign because the seq fence makes replay of
//! pre-snapshot records a no-op.
//!
//! ## Snapshot layout (paged / incremental)
//!
//! A table's snapshot is one *manifest* file (`{name}.snap`: a header
//! frame plus one `shardref` frame per shard) stitching together
//! per-shard row files (`{name}.shard{i}.snap`, one `shard` frame
//! each). Checkpoints rewrite only the shard files whose shard was
//! mutated since its file was last written; eviction (paged mode)
//! reuses the same files as its spill store. Shard files may be newer
//! than the manifest — eviction writes them between checkpoints — which
//! is safe because replay ops are full-row puts/deletes: replaying the
//! WAL suffix from the manifest's fence over a newer shard image is
//! idempotent. Pre-manifest snapshots (a single file with inline
//! `shard` frames) still recover.
//!
//! ## Crash model
//!
//! Atomicity is **per table commit**: one frame is applied whole or not
//! at all (under `group_commit = false`, the unit shrinks to one op).
//! There is no cross-table transaction marker — a catalog operation
//! that commits to several tables (e.g. a rule touching rules, locks,
//! replicas, requests) appends to each table's log independently, so a
//! torn tail landing *mid-operation* can recover some tables one commit
//! ahead of others. The simulator's `ProcessCrash` fires between driver
//! steps (operation boundaries), where per-table recovery implies full
//! cross-table consistency; power-loss-grade tearing mid-operation is
//! out of scope and would need a global commit epoch.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::common::checksum;
use crate::common::error::{Result, RucioError};
use crate::jsonx::Json;

/// Bytes of frame overhead before the payload (length + SHA-256).
const FRAME_HEADER: usize = 4 + 32;

/// A row type that can live in a durable table: JSON encodings for the
/// row and for its primary key (the `Remove` side of the log). All
/// catalog row types implement this in `core::persist`.
pub trait Durable: crate::db::Row {
    fn row_to_json(&self) -> Json;
    fn row_from_json(j: &Json) -> Result<Self>;
    fn key_to_json(key: &Self::Key) -> Json;
    fn key_from_json(j: &Json) -> Result<Self::Key>;
}

/// Durability knobs, from config `[db] fsync` / `[db] group_commit` /
/// `[db] wal_leader`.
#[derive(Debug, Clone, Copy)]
pub struct WalOptions {
    /// `fsync` after every commit frame (power-loss durability). Off by
    /// default: the sim's crash model is process death, where the OS
    /// page cache survives.
    pub fsync: bool,
    /// One frame per table commit (default) vs one frame (and fsync)
    /// per op — the group-commit ablation switch.
    pub group_commit: bool,
    /// Leader-based group commit (default): concurrent writers stage
    /// framed records into a short-lock buffer and one leader per
    /// commit window appends + fsyncs the whole window in a single
    /// write. `false` falls back to building and appending every frame
    /// under one global mutex — the `benches/abl_concurrency`
    /// contention baseline. Only meaningful with `group_commit = true`.
    pub leader: bool,
}

impl Default for WalOptions {
    fn default() -> Self {
        WalOptions { fsync: false, group_commit: true, leader: true }
    }
}

/// Live WAL shape, for monitoring (`analytics::reports::wal_stats`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WalStats {
    /// Bytes currently in the log file.
    pub bytes: u64,
    /// Frames currently in the log file (incl. barriers).
    pub records: u64,
    /// Commit frames appended since the last barrier.
    pub records_since_checkpoint: u64,
    /// Seq of the most recent barrier (0 = never checkpointed).
    pub last_checkpoint_seq: u64,
    /// Next record seq to be allocated.
    pub next_seq: u64,
    /// Commit windows flushed by a leader (each is one write + at most
    /// one fsync). In legacy mode every frame is its own window.
    pub flush_windows: u64,
    /// Total frames flushed across all windows; `flushed_frames /
    /// flush_windows` is the mean group-commit batch size.
    pub flushed_frames: u64,
    /// Largest number of frames ever coalesced into one window.
    pub max_window_frames: u64,
}

/// Outcome of one [`crate::db::Table::checkpoint`].
#[derive(Debug, Clone, Default)]
pub struct CheckpointStats {
    /// Live rows covered by the snapshot (hot and cold, written or
    /// skipped-clean).
    pub rows: usize,
    /// Bytes written this checkpoint (dirty shard files + manifest).
    pub snapshot_bytes: u64,
    /// The barrier seq fencing this snapshot.
    pub seq: u64,
    /// Shard files rewritten because their shard was dirty.
    pub shards_written: usize,
    /// Shards skipped because their on-disk file was still current.
    pub shards_skipped: usize,
}

/// Outcome of one [`crate::db::Table::compact_wal`].
#[derive(Debug, Clone, Default)]
pub struct CompactStats {
    /// Frames in the log before / after the fold.
    pub records_before: u64,
    pub records_after: u64,
    /// Log size in bytes before / after the fold.
    pub bytes_before: u64,
    pub bytes_after: u64,
    /// Individual ops superseded by a later op on the same key (or
    /// already covered by the snapshot fence) and dropped.
    pub ops_dropped: u64,
}

/// Paged-mode shape of one table, for monitoring and the memory-budget
/// smoke assertions (`analytics::reports::spill_stats`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Total shards in the table.
    pub shard_count: usize,
    /// Shards currently evicted to their spill files.
    pub cold_shards: usize,
    /// Rows resident in memory — the RSS proxy the budget bounds.
    pub hot_rows: usize,
    /// Rows living only in spill files.
    pub cold_rows: usize,
    /// Hot-row budget (0 = paging off).
    pub budget: usize,
    /// Shard evictions since attach.
    pub evictions: u64,
    /// Cold shards faulted back into memory by a mutation.
    pub fault_ins: u64,
    /// Point reads served straight from a cold shard's file.
    pub disk_reads: u64,
}

/// Outcome of one [`crate::db::Table::recover`].
#[derive(Debug, Clone, Default)]
pub struct RecoverStats {
    /// Rows loaded from the snapshot.
    pub snapshot_rows: usize,
    /// The snapshot's barrier seq (0 = no snapshot found).
    pub snapshot_seq: u64,
    /// Commit frames replayed from the WAL suffix.
    pub replayed_records: u64,
    /// Individual ops applied during replay.
    pub replayed_ops: u64,
    /// True when a torn (truncated/corrupt) tail was detected and
    /// discarded — the checksummed frame boundary guarantees the
    /// discarded record was never partially applied.
    pub torn_tail: bool,
}

/// Object-safe persistence handle a durable [`crate::db::Table`] exposes
/// so [`crate::db::Registry::checkpoint_all`] can drive snapshots
/// without knowing row types.
pub trait TablePersist: Send + Sync {
    fn table_name(&self) -> &'static str;
    fn checkpoint(&self) -> Result<CheckpointStats>;
    fn wal_stats(&self) -> Option<WalStats>;
    /// True when a checkpoint would change what's on disk: WAL records
    /// since the last barrier, or a shard dirtied since its file was
    /// written. Clean tables skip the snapshot sweep entirely.
    fn needs_checkpoint(&self) -> bool {
        true
    }
    /// Fold the WAL down to the final op per key (see
    /// [`crate::db::Table::compact_wal`]). Default: no-op.
    fn compact_wal(&self) -> Result<CompactStats> {
        Ok(CompactStats::default())
    }
    /// Evict least-recently-used shards until the hot-row count fits the
    /// memory budget. Returns shards evicted. Default: no-op.
    fn enforce_budget(&self) -> Result<usize> {
        Ok(0)
    }
    /// Paged-mode shape (hot/cold rows, budget, eviction counters).
    fn spill_stats(&self) -> SpillStats {
        SpillStats::default()
    }
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

fn frame_into(out: &mut Vec<u8>, payload: &Json) {
    let text = payload.to_string();
    let bytes = text.as_bytes();
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&checksum::sha256(bytes));
    out.extend_from_slice(bytes);
}

fn frame(payload: &Json) -> Vec<u8> {
    let mut out = Vec::new();
    frame_into(&mut out, payload);
    out
}

/// One decoded WAL frame.
#[derive(Debug, Clone)]
pub struct WalRecord {
    /// Record sequence number (`seq` field of the payload).
    pub seq: u64,
    pub payload: Json,
    /// Byte offset just past this frame — crash-point granularity for
    /// the torn-tail property tests.
    pub end_offset: u64,
}

/// Result of scanning a framed file leniently (WAL semantics: a torn
/// tail is expected after a crash and simply discarded).
#[derive(Debug, Clone, Default)]
pub struct WalReadResult {
    pub records: Vec<WalRecord>,
    /// Length of the valid prefix in bytes.
    pub valid_bytes: u64,
    /// True when trailing bytes after the valid prefix were discarded.
    pub torn: bool,
}

/// Read every complete, checksum-valid frame from `path`. A missing file
/// reads as empty. Stops (and flags `torn`) at the first incomplete or
/// corrupt frame.
pub fn read_records(path: &Path) -> Result<WalReadResult> {
    if !path.exists() {
        return Ok(WalReadResult::default());
    }
    let data = std::fs::read(path)?;
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        if pos == data.len() {
            break;
        }
        if pos + FRAME_HEADER > data.len() {
            break; // torn header
        }
        let mut len_bytes = [0u8; 4];
        len_bytes.copy_from_slice(&data[pos..pos + 4]);
        let len = u32::from_le_bytes(len_bytes) as usize;
        if pos + FRAME_HEADER + len > data.len() {
            break; // torn payload
        }
        let digest = &data[pos + 4..pos + FRAME_HEADER];
        let payload = &data[pos + FRAME_HEADER..pos + FRAME_HEADER + len];
        if &checksum::sha256(payload)[..] != digest {
            break; // corrupt frame: treat like a torn tail
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(json) = Json::parse(text) else { break };
        pos += FRAME_HEADER + len;
        let seq = json.opt_u64("seq").unwrap_or(0);
        records.push(WalRecord { seq, payload: json, end_offset: pos as u64 });
    }
    Ok(WalReadResult { records, valid_bytes: pos as u64, torn: pos < data.len() })
}

/// Read a framed file strictly (snapshot semantics: snapshots are
/// written atomically, so a torn snapshot is corruption, not a crash
/// artifact). Returns the payloads in order.
pub fn read_frames(path: &Path) -> Result<Vec<Json>> {
    let scan = read_records(path)?;
    if scan.torn {
        return Err(RucioError::DatabaseError(format!(
            "{}: torn or corrupt frame at byte {}",
            path.display(),
            scan.valid_bytes
        )));
    }
    Ok(scan.records.into_iter().map(|r| r.payload).collect())
}

/// Write `frames` to `path` atomically: temp file, optional fsync, then
/// rename. Returns the file size. Used for snapshots and the manifest.
pub fn write_frames_atomic(path: &Path, frames: &[Json], fsync: bool) -> Result<u64> {
    let mut buf = Vec::new();
    for f in frames {
        frame_into(&mut buf, f);
    }
    let tmp = tmp_path(path);
    {
        let mut file = File::create(&tmp)?;
        file.write_all(&buf)?;
        if fsync {
            file.sync_data()?;
        }
    }
    std::fs::rename(&tmp, path)?;
    Ok(buf.len() as u64)
}

/// Snapshot manifest for table `name` under the durability dir (also
/// the whole snapshot in the pre-manifest format).
pub fn snapshot_file(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.snap"))
}

/// Per-shard snapshot/spill file for shard `i` of table `name`.
pub fn shard_snapshot_file(dir: &Path, name: &str, i: usize) -> PathBuf {
    dir.join(format!("{name}.shard{i}.snap"))
}

/// Remove shard files left behind by an older, wider shard layout
/// (indices at or past `shard_count`). Best-effort: IO errors on the
/// directory scan read as "nothing to remove".
pub fn remove_orphan_shard_files(dir: &Path, name: &str, shard_count: usize) {
    let prefix = format!("{name}.shard");
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let file_name = entry.file_name();
        let Some(fname) = file_name.to_str() else { continue };
        let Some(rest) = fname.strip_prefix(&prefix) else { continue };
        let Some(idx) = rest.strip_suffix(".snap") else { continue };
        if let Ok(i) = idx.parse::<usize>() {
            if i >= shard_count {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

/// WAL file for table `name` under the durability dir.
pub fn wal_file(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.wal"))
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

// ---------------------------------------------------------------------
// the log
// ---------------------------------------------------------------------

/// One reserved position in the staging buffer. `frame` stays `None`
/// between seq reservation and deposit; the leader only drains the
/// contiguous deposited prefix, so an in-flight writer blocks the
/// window at its slot, never loses it.
struct Slot {
    frame: Option<Vec<u8>>,
    is_barrier: bool,
}

/// The short-lock staging buffer writers enqueue into. Slots are held
/// in seq order: `slots[i]` has seq `base_seq + i`.
struct Staging {
    next_seq: u64,
    /// Seq of `slots[0]`; meaningful only while `slots` is non-empty.
    base_seq: u64,
    slots: std::collections::VecDeque<Slot>,
}

/// Everything guarded by the file mutex. Whoever holds it while frames
/// are staged is the leader for that commit window.
struct FileState {
    file: File,
    bytes: u64,
    records: u64,
    last_barrier_seq: u64,
    records_since_barrier: u64,
}

/// A per-table append-only write-ahead log.
///
/// In leader mode (the default) concurrent writers reserve a seq,
/// build + checksum their frame outside any lock, deposit it into the
/// staging buffer, and then race for the file mutex: the winner is the
/// leader for the commit window and appends every deposited frame in
/// one write with at most one fsync; the losers block on the mutex and
/// find their seq already durable when they get it. Tables call in
/// while holding their shard write locks, so WAL order matches commit
/// order per key. With `leader = false` every append serializes on the
/// file mutex (the pre-group-commit baseline kept for the
/// `benches/abl_concurrency` ablation).
pub struct Wal {
    path: PathBuf,
    opts: WalOptions,
    staging: Mutex<Staging>,
    file: Mutex<FileState>,
    /// Highest seq whose fate (durable or failed) has been decided. A
    /// writer whose seq is at or below this watermark can return
    /// without touching the file.
    flushed_seq: AtomicU64,
    /// Highest seq in any failed flush window (0 = none). Coarse on
    /// purpose: a slow writer from an *earlier, successful* window can
    /// read a false `Err` after a later window fails — retrying a
    /// durable commit is safe (replay ops are idempotent), dropping a
    /// failed one is not.
    failed_up_to: AtomicU64,
    // Contention telemetry for `analytics::reports::contention_stats`.
    flush_windows: AtomicU64,
    flushed_frames: AtomicU64,
    max_window_frames: AtomicU64,
}

impl Wal {
    /// Open (or create) the log at `path`, scanning existing frames to
    /// restore counters. A torn tail is truncated away so new appends
    /// always follow a valid frame.
    pub fn open(path: &Path, opts: WalOptions) -> Result<Wal> {
        let scan = read_records(path)?;
        if scan.torn {
            let f = OpenOptions::new().write(true).create(true).open(path)?;
            f.set_len(scan.valid_bytes)?;
        }
        let mut next_seq = 1u64;
        let mut last_barrier_seq = 0u64;
        let mut records_since_barrier = 0u64;
        for r in &scan.records {
            next_seq = next_seq.max(r.seq + 1);
            if r.payload.opt_str("k") == Some("b") {
                last_barrier_seq = r.seq;
                records_since_barrier = 0;
            } else {
                records_since_barrier += 1;
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Wal {
            path: path.to_path_buf(),
            opts,
            staging: Mutex::new(Staging {
                next_seq,
                base_seq: next_seq,
                slots: std::collections::VecDeque::new(),
            }),
            file: Mutex::new(FileState {
                file,
                bytes: scan.valid_bytes,
                records: scan.records.len() as u64,
                last_barrier_seq,
                records_since_barrier,
            }),
            flushed_seq: AtomicU64::new(next_seq - 1),
            failed_up_to: AtomicU64::new(0),
            flush_windows: AtomicU64::new(0),
            flushed_frames: AtomicU64::new(0),
            max_window_frames: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn fsync_enabled(&self) -> bool {
        self.opts.fsync
    }

    fn leader_mode(&self) -> bool {
        self.opts.group_commit && self.opts.leader
    }

    /// Append one already-framed byte run (one frame in legacy mode, a
    /// whole commit window in leader mode). On any IO error the file is
    /// rolled back to the last known-good frame boundary, so a partial
    /// append can never poison the frames that follow it — only this
    /// run is lost, not everything appended after it.
    fn append_bytes(fs: &mut FileState, buf: &[u8], fsync: bool) -> Result<()> {
        let mut res = fs.file.write_all(buf).map_err(RucioError::from);
        if res.is_ok() && fsync {
            res = fs.file.sync_data().map_err(RucioError::from);
        }
        match res {
            Ok(()) => {
                fs.bytes += buf.len() as u64;
                Ok(())
            }
            Err(e) => {
                let _ = fs.file.set_len(fs.bytes);
                Err(e)
            }
        }
    }

    /// Reserve the next seq and an empty slot for it. Lock discipline:
    /// the staging mutex is only ever taken bare or *inside* the file
    /// mutex, never the other way around.
    fn reserve_slot(&self, is_barrier: bool) -> u64 {
        let mut s = self.staging.lock().unwrap();
        let seq = s.next_seq;
        s.next_seq += 1;
        if s.slots.is_empty() {
            s.base_seq = seq;
        }
        s.slots.push_back(Slot { frame: None, is_barrier });
        seq
    }

    /// Fill the slot reserved for `seq` with its framed bytes. The slot
    /// is guaranteed to still exist: leaders never drain past an
    /// undeposited slot, and ours is undeposited until now.
    fn deposit(&self, seq: u64, buf: Vec<u8>) {
        let mut s = self.staging.lock().unwrap();
        let idx = (seq - s.base_seq) as usize;
        s.slots[idx].frame = Some(buf);
    }

    /// Resolve the fate of a flushed seq: `Err` if it fell in a failed
    /// window (see `failed_up_to` for why this is deliberately coarse).
    fn window_result(&self, seq: u64) -> Result<()> {
        if seq <= self.failed_up_to.load(Ordering::Acquire) {
            return Err(RucioError::DatabaseError(format!(
                "wal flush window containing seq {seq} failed"
            )));
        }
        Ok(())
    }

    /// Block until `seq` is durable (or its window has failed). The
    /// thread that wins the file mutex while frames are staged becomes
    /// the leader and flushes the whole deposited prefix in one write.
    fn flush_until(&self, seq: u64) -> Result<()> {
        loop {
            if self.flushed_seq.load(Ordering::Acquire) >= seq {
                return self.window_result(seq);
            }
            let mut fs = self.file.lock().unwrap();
            // A previous leader may have flushed us while we waited on
            // the mutex.
            if self.flushed_seq.load(Ordering::Acquire) >= seq {
                return self.window_result(seq);
            }
            // We are the leader: drain the contiguous deposited prefix.
            let mut buf = Vec::new();
            let mut meta: Vec<(u64, bool)> = Vec::new();
            {
                let mut s = self.staging.lock().unwrap();
                while matches!(s.slots.front(), Some(slot) if slot.frame.is_some()) {
                    let slot = s.slots.pop_front().unwrap();
                    let slot_seq = s.base_seq;
                    s.base_seq += 1;
                    buf.extend_from_slice(slot.frame.as_deref().unwrap());
                    meta.push((slot_seq, slot.is_barrier));
                }
            }
            if meta.is_empty() {
                // Our deposited slot is queued behind another writer's
                // reserved-but-undeposited one; it is mid-frame-build
                // with no locks held, so give it a beat and retry.
                drop(fs);
                std::thread::yield_now();
                continue;
            }
            let frames = meta.len() as u64;
            let upto = meta.last().unwrap().0;
            match Self::append_bytes(&mut fs, &buf, self.opts.fsync) {
                Ok(()) => {
                    fs.records += frames;
                    for (slot_seq, is_barrier) in &meta {
                        if *is_barrier {
                            fs.last_barrier_seq = *slot_seq;
                            fs.records_since_barrier = 0;
                        } else {
                            fs.records_since_barrier += 1;
                        }
                    }
                }
                Err(_) => {
                    // append_bytes rolled the file back; the whole
                    // window is gone, so mark every writer in it failed.
                    self.failed_up_to.fetch_max(upto, Ordering::AcqRel);
                }
            }
            self.flush_windows.fetch_add(1, Ordering::Relaxed);
            self.flushed_frames.fetch_add(frames, Ordering::Relaxed);
            self.max_window_frames.fetch_max(frames, Ordering::Relaxed);
            self.flushed_seq.store(upto, Ordering::Release);
            drop(fs);
            if upto >= seq {
                return self.window_result(seq);
            }
        }
    }

    /// Allocate the next seq while already holding the file mutex —
    /// the legacy path's ordering guarantee (file → staging is the one
    /// permitted nesting).
    fn alloc_seq_locked(&self) -> u64 {
        let mut s = self.staging.lock().unwrap();
        let seq = s.next_seq;
        s.next_seq += 1;
        s.base_seq = s.next_seq;
        seq
    }

    /// Append one table commit. Under group commit the whole op list is
    /// one frame; in leader mode the frame is staged and flushed as
    /// part of a commit window (one write, at most one fsync for the
    /// whole window). With `group_commit = false` each op is its own
    /// frame with its own fsync — the per-record baseline.
    pub fn commit(&self, ops: Vec<Json>) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        if self.leader_mode() {
            let seq = self.reserve_slot(false);
            let payload =
                Json::obj().with("k", "c").with("seq", seq).with("ops", Json::Arr(ops));
            self.deposit(seq, frame(&payload));
            return self.flush_until(seq);
        }
        let mut fs = self.file.lock().unwrap();
        if self.opts.group_commit {
            let seq = self.alloc_seq_locked();
            let payload =
                Json::obj().with("k", "c").with("seq", seq).with("ops", Json::Arr(ops));
            let buf = frame(&payload);
            Self::append_bytes(&mut fs, &buf, self.opts.fsync)?;
            fs.records += 1;
            fs.records_since_barrier += 1;
            self.note_window(1);
            self.flushed_seq.store(seq, Ordering::Release);
        } else {
            for op in ops {
                let seq = self.alloc_seq_locked();
                let payload =
                    Json::obj().with("k", "c").with("seq", seq).with("ops", Json::Arr(vec![op]));
                let buf = frame(&payload);
                Self::append_bytes(&mut fs, &buf, self.opts.fsync)?;
                fs.records += 1;
                fs.records_since_barrier += 1;
                self.note_window(1);
                self.flushed_seq.store(seq, Ordering::Release);
            }
        }
        Ok(())
    }

    fn note_window(&self, frames: u64) {
        self.flush_windows.fetch_add(1, Ordering::Relaxed);
        self.flushed_frames.fetch_add(frames, Ordering::Relaxed);
        self.max_window_frames.fetch_max(frames, Ordering::Relaxed);
    }

    /// Append a snapshot barrier and return its seq. The caller must
    /// hold the table's shard locks so the fence position is exact —
    /// which also means no commit can be mid-flight in staging, so the
    /// barrier's window contains exactly the barrier.
    pub fn barrier(&self) -> Result<u64> {
        if self.leader_mode() {
            let seq = self.reserve_slot(true);
            self.deposit(seq, frame(&Json::obj().with("k", "b").with("seq", seq)));
            self.flush_until(seq)?;
            return Ok(seq);
        }
        let mut fs = self.file.lock().unwrap();
        let seq = self.alloc_seq_locked();
        let buf = frame(&Json::obj().with("k", "b").with("seq", seq));
        Self::append_bytes(&mut fs, &buf, self.opts.fsync)?;
        fs.records += 1;
        fs.last_barrier_seq = seq;
        fs.records_since_barrier = 0;
        self.note_window(1);
        self.flushed_seq.store(seq, Ordering::Release);
        Ok(seq)
    }

    /// Replace the log's contents with `payloads`, atomically (temp file
    /// + rename), reopening the append handle on the new file and
    /// rebuilding the counters. The caller holds the file mutex (owns
    /// `fs`), so no flush can interleave.
    fn replace_locked(&self, fs: &mut FileState, payloads: &[Json]) -> Result<()> {
        let mut buf = Vec::new();
        for p in payloads {
            frame_into(&mut buf, p);
        }
        let tmp = tmp_path(&self.path);
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&buf)?;
            if self.opts.fsync {
                f.sync_data()?;
            }
        }
        std::fs::rename(&tmp, &self.path)?;
        fs.file = OpenOptions::new().append(true).open(&self.path)?;
        fs.bytes = buf.len() as u64;
        fs.records = payloads.len() as u64;
        fs.last_barrier_seq = 0;
        fs.records_since_barrier = 0;
        for p in payloads {
            if p.opt_str("k") == Some("b") {
                fs.last_barrier_seq = p.opt_u64("seq").unwrap_or(0);
                fs.records_since_barrier = 0;
            } else {
                fs.records_since_barrier += 1;
            }
        }
        Ok(())
    }

    /// Compact the log after a checkpoint: drop everything the barrier
    /// `seq` fences off, keeping the barrier frame plus any records
    /// appended after it — writers commit concurrently with the
    /// snapshot's file IO, and those suffix records are NOT covered by
    /// the snapshot. Atomic (temp file + rename); the append handle is
    /// reopened on the new file. The file mutex is held for the whole
    /// rewrite, so no flush is in flight and the on-disk file is exactly
    /// the flushed prefix; frames staged but unflushed (all with seq >
    /// `seq`) append to the reopened handle afterwards.
    pub fn truncate_to_barrier(&self, seq: u64) -> Result<()> {
        let mut fs = self.file.lock().unwrap();
        let scan = read_records(&self.path)?;
        let mut payloads = vec![Json::obj().with("k", "b").with("seq", seq)];
        payloads.extend(scan.records.into_iter().filter(|r| r.seq > seq).map(|r| r.payload));
        self.replace_locked(&mut fs, &payloads)
    }

    /// Rewrite the live log in place: `rewrite` maps the current records
    /// to replacement payloads, or returns `None` to leave the log
    /// untouched. Runs entirely under the file mutex with an atomic
    /// temp-file + rename swap, so concurrent committers simply wait and
    /// then append to the rewritten file. Seq allocation is untouched —
    /// callers must only drop or fold *existing* records, never renumber
    /// or invent seqs. Returns `(bytes_before, records_before,
    /// bytes_after, records_after)` when a rewrite happened.
    pub fn rewrite_locked<F>(&self, rewrite: F) -> Result<Option<(u64, u64, u64, u64)>>
    where
        F: FnOnce(&[WalRecord]) -> Option<Vec<Json>>,
    {
        let mut fs = self.file.lock().unwrap();
        let scan = read_records(&self.path)?;
        let (bytes_before, records_before) = (fs.bytes, fs.records);
        let Some(payloads) = rewrite(&scan.records) else {
            return Ok(None);
        };
        self.replace_locked(&mut fs, &payloads)?;
        Ok(Some((bytes_before, records_before, fs.bytes, fs.records)))
    }

    pub fn stats(&self) -> WalStats {
        let fs = self.file.lock().unwrap();
        let next_seq = self.staging.lock().unwrap().next_seq;
        WalStats {
            bytes: fs.bytes,
            records: fs.records,
            records_since_checkpoint: fs.records_since_barrier,
            last_checkpoint_seq: fs.last_barrier_seq,
            next_seq,
            flush_windows: self.flush_windows.load(Ordering::Relaxed),
            flushed_frames: self.flushed_frames.load(Ordering::Relaxed),
            max_window_frames: self.max_window_frames.load(Ordering::Relaxed),
        }
    }
}

/// Replay helper shared by table recovery and tests: the `(key, op)`
/// view of one commit frame's ops, decoded through a [`Durable`] type.
pub fn decode_ops<V: Durable>(record: &Json) -> Result<Vec<ReplayOp<V>>> {
    let ops = record
        .get("ops")
        .and_then(Json::as_arr)
        .ok_or_else(|| RucioError::DatabaseError("wal commit frame without ops".into()))?;
    let mut out = Vec::with_capacity(ops.len());
    for op in ops {
        match op.opt_str("o") {
            Some("u") => {
                let row = op
                    .get("row")
                    .ok_or_else(|| RucioError::DatabaseError("wal put op without row".into()))?;
                out.push(ReplayOp::Put(V::row_from_json(row)?));
            }
            Some("r") => {
                let key = op
                    .get("key")
                    .ok_or_else(|| RucioError::DatabaseError("wal del op without key".into()))?;
                out.push(ReplayOp::Del(V::key_from_json(key)?));
            }
            other => {
                return Err(RucioError::DatabaseError(format!(
                    "unknown wal op kind {other:?}"
                )));
            }
        }
    }
    Ok(out)
}

/// One decoded replay op.
pub enum ReplayOp<V: Durable> {
    /// Insert-or-replace (covers live inserts, upserts, and updates).
    Put(V),
    /// Remove by key (missing keys are no-ops on replay).
    Del(V::Key),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        static N: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let i = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!("rucio-wal-{}-{name}-{i}", std::process::id()))
    }

    fn op(i: u64) -> Json {
        Json::obj().with("o", "u").with("row", Json::obj().with("id", i))
    }

    #[test]
    fn commit_read_round_trip() {
        let path = tmp("rt");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1), op(2)]).unwrap();
        wal.commit(vec![op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert!(!scan.torn);
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[0].seq, 1);
        assert_eq!(scan.records[1].seq, 2);
        let ops = scan.records[0].payload.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops.len(), 2, "group commit: one frame for the batch");
        let stats = wal.stats();
        assert_eq!(stats.records, 2);
        assert_eq!(stats.records_since_checkpoint, 2);
        assert_eq!(stats.next_seq, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn per_record_mode_writes_one_frame_per_op() {
        let path = tmp("per");
        let wal = Wal::open(
            &path,
            WalOptions { fsync: false, group_commit: false, leader: true },
        )
        .unwrap();
        wal.commit(vec![op(1), op(2), op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert_eq!(scan.records.len(), 3);
        assert_eq!(scan.records[2].seq, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_detected_and_dropped_on_reopen() {
        let path = tmp("torn");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1)]).unwrap();
        wal.commit(vec![op(2)]).unwrap();
        let full = std::fs::metadata(&path).unwrap().len();
        drop(wal);
        // cut into the final frame
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 5).unwrap();
        drop(f);
        let scan = read_records(&path).unwrap();
        assert!(scan.torn);
        assert_eq!(scan.records.len(), 1, "only the complete frame survives");
        // reopen truncates the garbage and continues the seq
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), scan.valid_bytes);
        wal.commit(vec![op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert!(!scan.torn);
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[1].seq, 2, "seq continues past the valid prefix");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_byte_invalidates_the_frame() {
        let path = tmp("corrupt");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1)]).unwrap();
        wal.commit(vec![op(2)]).unwrap();
        drop(wal);
        let mut data = std::fs::read(&path).unwrap();
        let last = data.len() - 3;
        data[last] ^= 0xFF; // flip a payload byte inside the second frame
        std::fs::write(&path, &data).unwrap();
        let scan = read_records(&path).unwrap();
        assert!(scan.torn, "checksum mismatch reads as a torn tail");
        assert_eq!(scan.records.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn barrier_and_truncate_fence_the_log() {
        let path = tmp("barrier");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1)]).unwrap();
        let seq = wal.barrier().unwrap();
        assert_eq!(seq, 2);
        wal.commit(vec![op(2)]).unwrap();
        let stats = wal.stats();
        assert_eq!(stats.last_checkpoint_seq, 2);
        assert_eq!(stats.records_since_checkpoint, 1);
        wal.truncate_to_barrier(seq).unwrap();
        // The commit appended *after* the barrier is not covered by the
        // snapshot the barrier fences — truncation must keep it.
        let scan = read_records(&path).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[0].payload.opt_str("k"), Some("b"));
        assert_eq!(scan.records[1].seq, 3);
        assert_eq!(wal.stats().records_since_checkpoint, 1);
        // appends continue with the pre-truncation seq counter
        wal.commit(vec![op(3)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert_eq!(scan.records[2].seq, 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rewrite_locked_folds_and_preserves_counters() {
        let path = tmp("rewrite");
        let wal = Wal::open(&path, WalOptions::default()).unwrap();
        wal.commit(vec![op(1)]).unwrap();
        wal.commit(vec![op(2)]).unwrap();
        wal.commit(vec![op(3)]).unwrap();
        // fold the three commits down to the last one
        let res = wal
            .rewrite_locked(|records| {
                Some(vec![records.last().unwrap().payload.clone()])
            })
            .unwrap()
            .unwrap();
        assert_eq!((res.1, res.3), (3, 1), "records 3 -> 1");
        assert!(res.2 < res.0, "bytes shrank");
        let stats = wal.stats();
        assert_eq!(stats.records, 1);
        assert_eq!(stats.records_since_checkpoint, 1);
        assert_eq!(stats.next_seq, 4, "seq allocation untouched");
        // a `None` rewrite leaves the log alone
        assert!(wal.rewrite_locked(|_| None).unwrap().is_none());
        // appends continue on the rewritten file
        wal.commit(vec![op(4)]).unwrap();
        let scan = read_records(&path).unwrap();
        assert!(!scan.torn);
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[1].seq, 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn snapshot_frames_round_trip_and_reject_corruption() {
        let path = tmp("snap");
        let frames =
            vec![Json::obj().with("k", "snap").with("ckpt", 7u64), Json::obj().with("i", 0)];
        let bytes = write_frames_atomic(&path, &frames, false).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let back = read_frames(&path).unwrap();
        assert_eq!(back, frames);
        // a torn snapshot is an error, not a silent partial read
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(bytes - 2).unwrap();
        drop(f);
        assert!(read_frames(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_reads_empty() {
        let path = tmp("missing");
        let scan = read_records(&path).unwrap();
        assert!(scan.records.is_empty() && !scan.torn && scan.valid_bytes == 0);
    }

    #[test]
    fn legacy_mutex_mode_matches_leader_mode_on_disk() {
        let (pa, pb) = (tmp("legacy"), tmp("leader"));
        let legacy = Wal::open(
            &pa,
            WalOptions { fsync: false, group_commit: true, leader: false },
        )
        .unwrap();
        let leader = Wal::open(&pb, WalOptions::default()).unwrap();
        for wal in [&legacy, &leader] {
            wal.commit(vec![op(1), op(2)]).unwrap();
            wal.commit(vec![op(3)]).unwrap();
        }
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        let (sa, sb) = (legacy.stats(), leader.stats());
        assert_eq!((sa.records, sa.next_seq), (sb.records, sb.next_seq));
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
    }

    #[test]
    fn concurrent_commits_all_durable_and_seq_dense() {
        let path = tmp("conc");
        let wal = std::sync::Arc::new(Wal::open(&path, WalOptions::default()).unwrap());
        let threads = 8;
        let per_thread = 50;
        let mut handles = Vec::new();
        for t in 0..threads {
            let wal = wal.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    wal.commit(vec![op((t * per_thread + i) as u64)]).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let scan = read_records(&path).unwrap();
        assert!(!scan.torn);
        assert_eq!(scan.records.len(), threads * per_thread);
        // Seqs are dense and strictly increasing in file order: the
        // leader drains windows in reservation order.
        for (i, r) in scan.records.iter().enumerate() {
            assert_eq!(r.seq, i as u64 + 1);
        }
        let stats = wal.stats();
        assert_eq!(stats.flushed_frames, (threads * per_thread) as u64);
        assert!(stats.flush_windows <= stats.flushed_frames);
        assert!(stats.max_window_frames >= 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn barrier_under_concurrent_commits_keeps_a_consistent_fence() {
        let path = tmp("concbar");
        let wal = std::sync::Arc::new(Wal::open(&path, WalOptions::default()).unwrap());
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let wal = wal.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    wal.commit(vec![op(t * 1_000_000 + i)]).unwrap();
                    i += 1;
                }
            }));
        }
        for _ in 0..20 {
            wal.barrier().unwrap();
            std::thread::yield_now();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        let scan = read_records(&path).unwrap();
        assert!(!scan.torn);
        // Every barrier frame's seq is exactly where it sits in the log.
        for (i, r) in scan.records.iter().enumerate() {
            assert_eq!(r.seq, i as u64 + 1);
        }
        let stats = wal.stats();
        assert_eq!(stats.records, scan.records.len() as u64);
        std::fs::remove_file(&path).ok();
    }
}
