//! FTS simulator — the File Transfer Service middleware substitute
//! (paper §1.3: "FTS is a hard dependency for Rucio instances which
//! require third party copy ... Rucio decides which files to move, groups
//! them in transfer requests, submits the transfer requests to FTS,
//! monitors the progress of the transfers, retries in case of errors").
//!
//! Lifecycle per transfer: `Submitted → Active → Done | Failed`.
//! * A configurable number of transfers are active per directed link;
//!   the rest wait in per-link FIFO queues (FTS's own scheduling).
//! * Active transfers progress by integrating the fair-share bandwidth
//!   from [`crate::netsim::Network`] over virtual time.
//! * On completion the file materializes on the destination
//!   [`crate::storagesim::StorageSystem`]; source-read and destination-
//!   write failures, link quality, and checksum mismatches produce
//!   `Failed` states with reasons — exactly the signal the conveyor's
//!   poller/receiver/finisher chain consumes.
//! * Completion events are published to the [`crate::mq::Broker`] topic
//!   `transfer.fts` (the paper's "transfer-receiver daemon observes a
//!   message queue" path).
//!
//! Multiple independent [`FtsServer`]s model the paper's redundant global
//! FTS deployment; the conveyor shards jobs across them.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::common::clock::EpochMs;
use crate::common::error::RucioError;
use crate::common::prng::Prng;
use crate::jsonx::Json;
use crate::mq::{Broker, Message};
use crate::netsim::Network;
use crate::storagesim::Fleet;
#[cfg(test)]
use crate::storagesim::synthetic_adler32;

/// Failure reason emitted when the *source* copy fails checksum
/// verification. The rule engine blames the source replica on exactly
/// this reason (`Catalog::on_transfer_failed`) — shared as a constant so
/// the cross-module contract cannot drift on wording or casing.
pub const REASON_SOURCE_CHECKSUM: &str = "CHECKSUM mismatch at source";

/// Transfer request handed to FTS by the conveyor submitter.
#[derive(Debug, Clone)]
pub struct TransferJob {
    /// Rucio request id this transfer satisfies (round-trips in events).
    pub request_id: u64,
    pub src_rse: String,
    pub dst_rse: String,
    /// Sites for network lookup (RSE attribute `site`).
    pub src_site: String,
    pub dst_site: String,
    pub src_pfn: String,
    pub dst_pfn: String,
    pub bytes: u64,
    /// Expected checksum (catalog value); verified on arrival.
    pub adler32: String,
    /// Activity share (paper Fig 6: "requests submitted to FTS split by
    /// activity").
    pub activity: String,
    /// Scheduling priority (1–5): on a contended link, queued jobs start
    /// highest-priority first (FIFO within a priority level).
    pub priority: u8,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferState {
    Submitted,
    Active,
    Done,
    Failed,
}

#[derive(Debug, Clone)]
pub struct Transfer {
    pub id: u64,
    pub job: TransferJob,
    pub state: TransferState,
    pub submitted_at: EpochMs,
    pub started_at: Option<EpochMs>,
    pub finished_at: Option<EpochMs>,
    pub bytes_done: f64,
    pub reason: Option<String>,
}

struct Inner {
    next_id: u64,
    transfers: BTreeMap<u64, Transfer>,
    /// Per-link queues of submitted transfer ids, bucketed by job
    /// priority: starts pop the head of the highest non-empty bucket —
    /// O(log buckets) instead of scanning the whole link queue — and stay
    /// FIFO within a priority level. Empty buckets are pruned on pop.
    queues: BTreeMap<(String, String), BTreeMap<u8, VecDeque<u64>>>,
    /// Active ids per link (bounded by `max_active_per_link`).
    active: BTreeMap<(String, String), Vec<u64>>,
    last_advance: EpochMs,
    rng: Prng,
    // counters for fig6 / monitoring
    submitted_total: u64,
    submitted_by_activity: BTreeMap<String, u64>,
    done_total: u64,
    failed_total: u64,
}

/// One FTS server instance.
pub struct FtsServer {
    pub name: String,
    pub max_active_per_link: usize,
    net: Arc<Network>,
    fleet: Arc<Fleet>,
    broker: Option<Broker>,
    /// Server reachability (chaos scenarios): while offline the engine
    /// freezes — no starts, no progress, no completions — and the conveyor
    /// routes submissions to the surviving servers. State is preserved, so
    /// in-flight transfers resume where they stopped on recovery.
    online: AtomicBool,
    inner: Mutex<Inner>,
}

impl FtsServer {
    pub fn new(name: &str, net: Arc<Network>, fleet: Arc<Fleet>, broker: Option<Broker>) -> Self {
        FtsServer {
            name: name.to_string(),
            max_active_per_link: 20,
            net,
            fleet,
            broker,
            online: AtomicBool::new(true),
            inner: Mutex::new(Inner {
                next_id: 1,
                transfers: BTreeMap::new(),
                queues: BTreeMap::new(),
                active: BTreeMap::new(),
                last_advance: 0,
                rng: Prng::new(0xF75),
                submitted_total: 0,
                submitted_by_activity: BTreeMap::new(),
                done_total: 0,
                failed_total: 0,
            }),
        }
    }

    pub fn with_max_active(mut self, n: usize) -> Self {
        self.max_active_per_link = n;
        self
    }

    /// Seed the quality-roll PRNG (determinism plumbing from the grid
    /// builder).
    pub fn with_seed(self, seed: u64) -> Self {
        self.inner.lock().unwrap().rng = Prng::new(seed);
        self
    }

    /// Take the server down / bring it back (chaos scenarios).
    pub fn set_online(&self, online: bool) {
        self.online.store(online, Ordering::Relaxed);
    }

    pub fn is_online(&self) -> bool {
        self.online.load(Ordering::Relaxed)
    }

    /// Submit a batch of jobs; returns FTS transfer ids (same order).
    pub fn submit(&self, jobs: Vec<TransferJob>, now: EpochMs) -> Vec<u64> {
        let mut inner = self.inner.lock().unwrap();
        let mut ids = Vec::with_capacity(jobs.len());
        for job in jobs {
            let id = inner.next_id;
            inner.next_id += 1;
            let link = (job.src_site.clone(), job.dst_site.clone());
            let priority = job.priority;
            inner.submitted_total += 1;
            *inner
                .submitted_by_activity
                .entry(job.activity.clone())
                .or_insert(0) += 1;
            inner.transfers.insert(
                id,
                Transfer {
                    id,
                    job,
                    state: TransferState::Submitted,
                    submitted_at: now,
                    started_at: None,
                    finished_at: None,
                    bytes_done: 0.0,
                    reason: None,
                },
            );
            inner
                .queues
                .entry(link)
                .or_default()
                .entry(priority)
                .or_default()
                .push_back(id);
            ids.push(id);
        }
        ids
    }

    /// Poll transfer states (conveyor-poller path). Unknown ids are skipped.
    pub fn poll(&self, ids: &[u64]) -> Vec<Transfer> {
        let inner = self.inner.lock().unwrap();
        ids.iter()
            .filter_map(|id| inner.transfers.get(id).cloned())
            .collect()
    }

    /// Cancel a submitted/active transfer.
    pub fn cancel(&self, id: u64, now: EpochMs) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let Some(t) = inner.transfers.get(&id) else { return false };
        if matches!(t.state, TransferState::Done | TransferState::Failed) {
            return false;
        }
        let link = (t.job.src_site.clone(), t.job.dst_site.clone());
        let was_active = t.state == TransferState::Active;
        if let Some(buckets) = inner.queues.get_mut(&link) {
            for q in buckets.values_mut() {
                q.retain(|x| *x != id);
            }
        }
        if let Some(a) = inner.active.get_mut(&link) {
            a.retain(|x| *x != id);
        }
        if was_active {
            self.net.release(&link.0, &link.1);
        }
        let t = inner.transfers.get_mut(&id).unwrap();
        t.state = TransferState::Failed;
        t.finished_at = Some(now);
        t.reason = Some("canceled".into());
        true
    }

    /// Advance the transfer engine to `now`: start queued transfers up to
    /// the per-link cap, integrate progress, complete/fail.
    pub fn advance(&self, now: EpochMs) {
        let mut inner = self.inner.lock().unwrap();
        let dt_ms = (now - inner.last_advance).max(0);
        inner.last_advance = now;
        // Downtime freezes the engine; advancing last_advance above means
        // the outage window contributes zero transfer progress.
        if !self.is_online() {
            return;
        }

        // 1. progress active transfers
        let active_snapshot: Vec<(String, String, u64)> = inner
            .active
            .iter()
            .flat_map(|((s, d), ids)| ids.iter().map(move |id| (s.clone(), d.clone(), *id)))
            .collect();
        let mut finished: Vec<(u64, bool, Option<String>)> = Vec::new();
        for (src, dst, id) in active_snapshot {
            let share = self.net.share_bps(&src, &dst) as f64;
            let t = inner.transfers.get_mut(&id).unwrap();
            t.bytes_done += share * dt_ms as f64 / 1000.0;
            if t.bytes_done >= t.job.bytes as f64 {
                // Completion: roll link quality, verify checksum, write dst.
                let quality = self.net.link(&src, &dst).quality;
                let ok = {
                    let roll = inner.rng.f64();
                    roll < quality
                };
                if !ok {
                    finished.push((id, false, Some("TRANSFER network error".into())));
                    continue;
                }
                let t = inner.transfers.get(&id).unwrap().clone();
                // checksum verification against the catalog value (§2.2:
                // checksums are enforced whenever a file is transferred)
                let src_sys = self.fleet.get(&t.job.src_rse);
                let src_ok = match &src_sys {
                    Some(sys) => match sys.stat(&t.job.src_pfn) {
                        Ok(f) => Some(f.adler32),
                        Err(e) => {
                            finished.push((id, false, Some(format!("SOURCE {e}"))));
                            None
                        }
                    },
                    None => {
                        finished.push((id, false, Some("SOURCE rse unknown".into())));
                        None
                    }
                };
                let Some(src_adler) = src_ok else { continue };
                if src_adler != t.job.adler32 {
                    finished.push((id, false, Some(REASON_SOURCE_CHECKSUM.into())));
                    continue;
                }
                match self.fleet.get(&t.job.dst_rse) {
                    Some(dst_sys) => match dst_sys.put(&t.job.dst_pfn, t.job.bytes, now) {
                        Ok(()) => finished.push((id, true, None)),
                        Err(RucioError::Duplicate(_)) => {
                            // The destination already holds the file (e.g.
                            // an earlier transfer landed after its request
                            // was canceled): success iff the bytes match.
                            // A transient stat failure stays retryable and
                            // must not masquerade as a checksum mismatch.
                            match dst_sys.stat(&t.job.dst_pfn) {
                                Ok(f) if f.adler32 == t.job.adler32 => {
                                    finished.push((id, true, None))
                                }
                                Ok(_) => finished.push((
                                    id,
                                    false,
                                    Some("DESTINATION exists with checksum mismatch".into()),
                                )),
                                Err(e) => finished
                                    .push((id, false, Some(format!("DESTINATION {e}")))),
                            }
                        }
                        Err(e) => finished.push((id, false, Some(format!("DESTINATION {e}")))),
                    },
                    None => finished.push((id, false, Some("DESTINATION rse unknown".into()))),
                }
            }
        }

        // 2. apply completions
        for (id, ok, reason) in finished {
            let (link, job, submitted_at, started_at) = {
                let t = inner.transfers.get_mut(&id).unwrap();
                t.state = if ok { TransferState::Done } else { TransferState::Failed };
                t.finished_at = Some(now);
                t.reason = reason.clone();
                (
                    (t.job.src_site.clone(), t.job.dst_site.clone()),
                    t.job.clone(),
                    t.submitted_at,
                    t.started_at.unwrap_or(now),
                )
            };
            if let Some(a) = inner.active.get_mut(&link) {
                a.retain(|x| *x != id);
            }
            self.net.release(&link.0, &link.1);
            if ok {
                inner.done_total += 1;
                let elapsed = (now - started_at).max(1);
                self.net
                    .record_throughput(&link.0, &link.1, job.bytes as f64 * 1000.0 / elapsed as f64);
            } else {
                inner.failed_total += 1;
            }
            if let Some(broker) = &self.broker {
                let event = if ok { "transfer-done" } else { "transfer-failed" };
                let payload = Json::obj()
                    .with("request_id", job.request_id)
                    .with("transfer_id", id)
                    .with("fts", self.name.as_str())
                    .with("src_rse", job.src_rse.as_str())
                    .with("dst_rse", job.dst_rse.as_str())
                    .with("bytes", job.bytes)
                    .with("activity", job.activity.as_str())
                    .with("submitted_at", submitted_at)
                    .with("started_at", started_at)
                    .with("finished_at", now)
                    .with("reason", reason.as_deref().unwrap_or(""));
                broker.publish("transfer.fts", Message::new(event, payload, now));
            }
        }

        // 3. start queued transfers where capacity is free — per-link
        //    concurrency cap, highest job priority first (FIFO within a
        //    priority level)
        let links: Vec<(String, String)> = inner.queues.keys().cloned().collect();
        for link in links {
            loop {
                let active_n = inner.active.get(&link).map(|v| v.len()).unwrap_or(0);
                if active_n >= self.max_active_per_link {
                    break;
                }
                let popped = inner.queues.get_mut(&link).and_then(|buckets| {
                    let prio = buckets
                        .iter()
                        .rev()
                        .find(|(_, q)| !q.is_empty())
                        .map(|(p, _)| *p)?;
                    let q = buckets.get_mut(&prio)?;
                    let id = q.pop_front();
                    if q.is_empty() {
                        buckets.remove(&prio);
                    }
                    id
                });
                let Some(id) = popped else { break };
                let t = inner.transfers.get_mut(&id).unwrap();
                t.state = TransferState::Active;
                t.started_at = Some(now);
                inner.active.entry(link.clone()).or_default().push(id);
                self.net.acquire(&link.0, &link.1);
            }
        }
    }

    /// Remove terminal transfers older than `keep_ms` (bookkeeping GC).
    pub fn gc(&self, now: EpochMs, keep_ms: i64) {
        let mut inner = self.inner.lock().unwrap();
        inner.transfers.retain(|_, t| {
            !(matches!(t.state, TransferState::Done | TransferState::Failed)
                && t.finished_at.map(|f| now - f > keep_ms).unwrap_or(false))
        });
    }

    pub fn queue_depth(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .queues
            .values()
            .map(|b| b.values().map(|q| q.len()).sum::<usize>())
            .sum()
    }

    pub fn active_count(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.active.values().map(|v| v.len()).sum()
    }

    /// Active transfer count per directed `(src_site, dst_site)` link —
    /// the `sim::invariants` per-link cap check reads this.
    pub fn active_per_link(&self) -> Vec<((String, String), usize)> {
        let inner = self.inner.lock().unwrap();
        inner
            .active
            .iter()
            .map(|(link, ids)| (link.clone(), ids.len()))
            .collect()
    }

    /// (submitted, done, failed) totals.
    pub fn totals(&self) -> (u64, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.submitted_total, inner.done_total, inner.failed_total)
    }

    /// Fig 6 source data: cumulative submissions per activity.
    pub fn submitted_by_activity(&self) -> BTreeMap<String, u64> {
        self.inner.lock().unwrap().submitted_by_activity.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Link;
    use crate::storagesim::{StorageKind, StorageSystem};

    fn setup() -> (Arc<Network>, Arc<Fleet>, Broker) {
        let net = Arc::new(Network::new());
        net.set_link("SITE-A", "SITE-B", Link::new(1_000_000, 5, 1.0)); // 1 MB/s
        let fleet = Arc::new(Fleet::new());
        fleet.add(StorageSystem::new("A-DISK", StorageKind::Disk, u64::MAX));
        fleet.add(StorageSystem::new("B-DISK", StorageKind::Disk, u64::MAX));
        (net, fleet, Broker::new())
    }

    fn job(req: u64, bytes: u64) -> TransferJob {
        TransferJob {
            request_id: req,
            src_rse: "A-DISK".into(),
            dst_rse: "B-DISK".into(),
            src_site: "SITE-A".into(),
            dst_site: "SITE-B".into(),
            src_pfn: format!("/a/f{req}"),
            dst_pfn: format!("/b/f{req}"),
            bytes,
            adler32: synthetic_adler32(&format!("/a/f{req}"), bytes),
            activity: "Production".into(),
            priority: 3,
        }
    }

    fn seed_source(fleet: &Fleet, j: &TransferJob) {
        fleet.get(&j.src_rse).unwrap().put(&j.src_pfn, j.bytes, 0).unwrap();
    }

    #[test]
    fn transfer_completes_after_bandwidth_time() {
        let (net, fleet, broker) = setup();
        let sub = broker.subscribe("transfer.fts", None);
        let fts = FtsServer::new("fts1", net, fleet.clone(), Some(broker.clone()));
        let j = job(1, 2_000_000); // 2 MB over 1 MB/s = 2s
        seed_source(&fleet, &j);
        let ids = fts.submit(vec![j], 0);
        fts.advance(0); // starts it
        assert_eq!(fts.poll(&ids)[0].state, TransferState::Active);
        fts.advance(1_000);
        assert_eq!(fts.poll(&ids)[0].state, TransferState::Active);
        fts.advance(2_100);
        let t = &fts.poll(&ids)[0];
        assert_eq!(t.state, TransferState::Done, "reason={:?}", t.reason);
        // destination file exists
        assert!(fleet.get("B-DISK").unwrap().stat("/b/f1").is_ok());
        // event published
        let msgs = broker.poll("transfer.fts", sub, 10);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].event_type, "transfer-done");
        assert_eq!(msgs[0].payload.req_u64("request_id").unwrap(), 1);
    }

    #[test]
    fn missing_source_fails_with_reason() {
        let (net, fleet, broker) = setup();
        let fts = FtsServer::new("fts1", net, fleet, Some(broker));
        let j = job(2, 1000); // never seeded on source
        let ids = fts.submit(vec![j], 0);
        fts.advance(0);
        fts.advance(10_000);
        let t = &fts.poll(&ids)[0];
        assert_eq!(t.state, TransferState::Failed);
        assert!(t.reason.as_ref().unwrap().contains("SOURCE"), "{:?}", t.reason);
    }

    #[test]
    fn per_link_cap_queues_excess() {
        let (net, fleet, _b) = setup();
        let fts = FtsServer::new("fts1", net, fleet.clone(), None).with_max_active(2);
        let jobs: Vec<TransferJob> = (0..5).map(|i| job(10 + i, 10_000_000)).collect();
        for j in &jobs {
            seed_source(&fleet, j);
        }
        fts.submit(jobs, 0);
        fts.advance(0);
        assert_eq!(fts.active_count(), 2);
        assert_eq!(fts.queue_depth(), 3);
    }

    #[test]
    fn priority_jumps_the_link_queue() {
        let (net, fleet, _b) = setup();
        let fts = FtsServer::new("fts1", net, fleet.clone(), None).with_max_active(1);
        // 3 normal jobs, then a boosted one; cap 1 ⇒ strict start order
        let mut jobs: Vec<TransferJob> = (0..3).map(|i| job(700 + i, 1_000_000)).collect();
        let mut hot = job(710, 1_000_000);
        hot.priority = 5;
        jobs.push(hot);
        for j in &jobs {
            seed_source(&fleet, j);
        }
        let ids = fts.submit(jobs, 0);
        fts.advance(0);
        // the boosted job starts first despite arriving last; cap holds
        assert_eq!(fts.active_count(), 1);
        assert_eq!(fts.active_per_link()[0].1, 1);
        assert_eq!(fts.poll(&[ids[3]])[0].state, TransferState::Active, "boosted first");
        assert_eq!(fts.poll(&[ids[0]])[0].state, TransferState::Submitted);
        // when the slot frees, the rest drain in FIFO order
        fts.advance(1_100);
        assert_eq!(fts.poll(&[ids[3]])[0].state, TransferState::Done);
        assert_eq!(fts.poll(&[ids[0]])[0].state, TransferState::Active);
        assert_eq!(fts.poll(&[ids[1]])[0].state, TransferState::Submitted);
        fts.advance(2_200);
        assert_eq!(fts.poll(&[ids[1]])[0].state, TransferState::Active);
        assert_eq!(fts.poll(&[ids[2]])[0].state, TransferState::Submitted);
    }

    #[test]
    fn fair_share_slows_concurrent_transfers() {
        let (net, fleet, _b) = setup();
        let fts = FtsServer::new("fts1", net, fleet.clone(), None);
        let j1 = job(21, 1_000_000);
        let j2 = job(22, 1_000_000);
        seed_source(&fleet, &j1);
        seed_source(&fleet, &j2);
        let ids = fts.submit(vec![j1, j2], 0);
        fts.advance(0);
        // two transfers share 1 MB/s → each needs ~2s
        fts.advance(1_200);
        let polled = fts.poll(&ids);
        assert_eq!(polled[0].state, TransferState::Active);
        assert_eq!(polled[1].state, TransferState::Active);
        fts.advance(2_300);
        let polled = fts.poll(&ids);
        assert_eq!(polled[0].state, TransferState::Done);
        assert_eq!(polled[1].state, TransferState::Done);
    }

    #[test]
    fn poor_quality_link_fails_some() {
        let (net, fleet, _b) = setup();
        net.set_link("SITE-A", "SITE-B", Link::new(100_000_000, 5, 0.5));
        let fts = FtsServer::new("fts1", net, fleet.clone(), None);
        let jobs: Vec<TransferJob> = (0..100).map(|i| job(100 + i, 1000)).collect();
        for j in &jobs {
            seed_source(&fleet, j);
        }
        fts.submit(jobs, 0);
        for t in 1..30 {
            fts.advance(t * 1000);
        }
        let (sub, done, failed) = fts.totals();
        assert_eq!(sub, 100);
        assert_eq!(done + failed, 100);
        assert!((25..75).contains(&(failed as i64)), "failed={failed}");
    }

    #[test]
    fn activity_accounting_for_fig6() {
        let (net, fleet, _b) = setup();
        let fts = FtsServer::new("fts1", net, fleet.clone(), None);
        let mut j1 = job(300, 1000);
        j1.activity = "T0 Export".into();
        let j2 = job(301, 1000);
        seed_source(&fleet, &j1);
        seed_source(&fleet, &j2);
        fts.submit(vec![j1, j2], 0);
        let by_act = fts.submitted_by_activity();
        assert_eq!(by_act["T0 Export"], 1);
        assert_eq!(by_act["Production"], 1);
    }

    #[test]
    fn pre_existing_matching_destination_counts_as_done() {
        let (net, fleet, _b) = setup();
        let fts = FtsServer::new("fts1", net, fleet.clone(), None);
        let j = job(600, 1000);
        seed_source(&fleet, &j);
        // the destination file already exists with the right content
        fleet.get("B-DISK").unwrap().put(&j.dst_pfn, j.bytes, 0).unwrap();
        let ids = fts.submit(vec![j], 0);
        fts.advance(0);
        fts.advance(10_000);
        let t = &fts.poll(&ids)[0];
        assert_eq!(t.state, TransferState::Done, "reason={:?}", t.reason);
    }

    #[test]
    fn downtime_freezes_progress_and_resumes() {
        let (net, fleet, _b) = setup();
        let fts = FtsServer::new("fts1", net, fleet.clone(), None);
        let j = job(500, 2_000_000); // 2 MB over 1 MB/s = 2s of transfer
        seed_source(&fleet, &j);
        let ids = fts.submit(vec![j], 0);
        fts.advance(0); // starts
        fts.advance(1_000); // 1s of progress
        fts.set_online(false);
        // a long outage window: no progress accrues
        fts.advance(50_000);
        assert_eq!(fts.poll(&ids)[0].state, TransferState::Active);
        fts.set_online(true);
        // outage time was consumed (not banked): needs 1 more real second
        fts.advance(50_500);
        assert_eq!(fts.poll(&ids)[0].state, TransferState::Active);
        fts.advance(51_100);
        let t = &fts.poll(&ids)[0];
        assert_eq!(t.state, TransferState::Done, "reason={:?}", t.reason);
    }

    #[test]
    fn cancel_submitted_and_gc() {
        let (net, fleet, _b) = setup();
        let fts = FtsServer::new("fts1", net.clone(), fleet.clone(), None);
        let j = job(400, 1_000_000_000);
        seed_source(&fleet, &j);
        let ids = fts.submit(vec![j], 0);
        assert!(fts.cancel(ids[0], 500));
        assert!(!fts.cancel(ids[0], 600));
        fts.advance(1000);
        assert_eq!(fts.poll(&ids)[0].state, TransferState::Failed);
        fts.gc(100_000, 10_000);
        assert!(fts.poll(&ids).is_empty());
        assert_eq!(net.active_on("SITE-A", "SITE-B"), 0);
    }
}
