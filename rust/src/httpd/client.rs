//! Blocking HTTP client with connection reuse — the `requests.Session`
//! analog the Rucio client layer builds on.

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use super::{read_request, write_response, Request, Response};
use crate::common::error::{Result, RucioError};

/// A client bound to one base URL (e.g. `http://127.0.0.1:8080`), holding a
/// persistent connection and default headers (auth token).
pub struct HttpClient {
    host: String,
    port: u16,
    default_headers: Mutex<Vec<(String, String)>>,
    conn: Mutex<Option<TcpStream>>,
}

impl HttpClient {
    /// `base`: `http://host:port` (scheme optional).
    pub fn new(base: &str) -> Self {
        let trimmed = base.trim_start_matches("http://").trim_end_matches('/');
        let (host, port) = match trimmed.rsplit_once(':') {
            Some((h, p)) => (h.to_string(), p.parse().unwrap_or(80)),
            None => (trimmed.to_string(), 80),
        };
        HttpClient {
            host,
            port,
            default_headers: Mutex::new(Vec::new()),
            conn: Mutex::new(None),
        }
    }

    /// Set (or replace) a default header sent with every request — the
    /// `X-Rucio-Auth-Token` slot.
    pub fn set_header(&self, name: &str, value: &str) {
        let mut hs = self.default_headers.lock().unwrap();
        hs.retain(|(k, _)| !k.eq_ignore_ascii_case(name));
        hs.push((name.to_ascii_lowercase(), value.to_string()));
    }

    pub fn get(&self, path: &str) -> Result<Response> {
        self.send(Request::new("GET", path))
    }

    pub fn delete(&self, path: &str) -> Result<Response> {
        self.send(Request::new("DELETE", path))
    }

    pub fn post_json(&self, path: &str, body: &crate::jsonx::Json) -> Result<Response> {
        let mut req = Request::new("POST", path);
        req.body = body.to_string().into_bytes();
        req.headers
            .insert("content-type".into(), "application/json".into());
        self.send(req)
    }

    pub fn put_json(&self, path: &str, body: &crate::jsonx::Json) -> Result<Response> {
        let mut req = Request::new("PUT", path);
        req.body = body.to_string().into_bytes();
        req.headers
            .insert("content-type".into(), "application/json".into());
        self.send(req)
    }

    pub fn send(&self, mut req: Request) -> Result<Response> {
        for (k, v) in self.default_headers.lock().unwrap().iter() {
            req.headers.entry(k.clone()).or_insert_with(|| v.clone());
        }
        // One retry on a stale pooled connection.
        match self.send_once(&req, true) {
            Ok(resp) => Ok(resp),
            Err(_) => self.send_once(&req, false),
        }
    }

    fn send_once(&self, req: &Request, reuse: bool) -> Result<Response> {
        let mut guard = self.conn.lock().unwrap();
        let stream = match (reuse, guard.take()) {
            (true, Some(s)) => s,
            _ => {
                let s = TcpStream::connect((self.host.as_str(), self.port))
                    .map_err(|e| RucioError::HttpError(format!("connect: {e}")))?;
                s.set_read_timeout(Some(Duration::from_secs(60)))?;
                s.set_nodelay(true)?;
                s
            }
        };
        let mut writer = stream.try_clone()?;
        write_client_request(&mut writer, req)?;
        let mut reader = BufReader::new(stream);
        let resp = read_response(&mut reader)?;
        // Return connection to the pool.
        *guard = Some(reader.into_inner());
        Ok(resp)
    }
}

fn write_client_request<W: std::io::Write>(w: &mut W, req: &Request) -> Result<()> {
    let mut target = req.path.clone();
    if !req.query.is_empty() {
        let qs: Vec<String> = req
            .query
            .iter()
            .map(|(k, v)| format!("{}={}", super::percent_encode(k), super::percent_encode(v)))
            .collect();
        target.push('?');
        target.push_str(&qs.join("&"));
    }
    let mut head = format!("{} {} HTTP/1.1\r\n", req.method, target);
    head.push_str(&format!("host: dummy\r\ncontent-length: {}\r\n", req.body.len()));
    for (k, v) in &req.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(&req.body)?;
    w.flush()?;
    Ok(())
}

fn read_response<R: std::io::Read>(reader: &mut BufReader<R>) -> Result<Response> {
    use std::io::BufRead;
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        return Err(RucioError::HttpError("connection closed".into()));
    }
    let mut parts = line.trim_end().splitn(3, ' ');
    let _version = parts.next();
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| RucioError::HttpError(format!("bad status line: {line}")))?;
    let mut resp = Response::new(status);
    loop {
        let mut hl = String::new();
        let n = reader.read_line(&mut hl)?;
        if n == 0 {
            return Err(RucioError::HttpError("eof in response headers".into()));
        }
        let t = hl.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            resp.headers
                .insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = resp
        .header("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if len > 0 {
        let mut body = vec![0u8; len];
        std::io::Read::read_exact(reader, &mut body)?;
        resp.body = body;
    }
    Ok(resp)
}

// Silence unused warnings for symmetry helpers used only in tests today.
#[allow(dead_code)]
fn _helpers_used(req: &mut BufReader<&[u8]>) {
    let _ = read_request(req);
    let _ = write_response(&mut Vec::new(), &Response::new(200), false);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_url_parsing() {
        let c = HttpClient::new("http://127.0.0.1:8080/");
        assert_eq!(c.host, "127.0.0.1");
        assert_eq!(c.port, 8080);
        let c = HttpClient::new("localhost:99");
        assert_eq!(c.host, "localhost");
        assert_eq!(c.port, 99);
    }

    #[test]
    fn default_headers_attached() {
        let c = HttpClient::new("http://x:1");
        c.set_header("X-Rucio-Auth-Token", "abc");
        c.set_header("x-rucio-auth-token", "def"); // replaces
        let hs = c.default_headers.lock().unwrap();
        assert_eq!(hs.len(), 1);
        assert_eq!(hs[0].1, "def");
    }

    #[test]
    fn query_string_encoding() {
        let mut req = Request::new("GET", "/list");
        req.query.insert("name".into(), "a b".into());
        let mut out = Vec::new();
        write_client_request(&mut out, &req).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("GET /list?name=a%20b HTTP/1.1\r\n"), "{text}");
    }
}
