//! HTTP/1.1 server + client over `std::net` — the Apache/mod_wsgi +
//! `requests` substitute (paper §3.3: "Incoming REST calls are received by
//! a web server ... and relayed to a WSGI container").
//!
//! Scope: exactly what the Rucio REST surface needs — request-line +
//! headers + `Content-Length` bodies, a path router with `{placeholders}`,
//! query strings, keep-alive, streamed (chunked) NDJSON list responses,
//! and a blocking client. TLS is out of scope (the paper's transport
//! security is terminated at the load balancer anyway).

pub mod client;
pub mod router;
pub mod server;

pub use client::HttpClient;
pub use router::{Handler, Router};
pub use server::HttpServer;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

use crate::common::error::{Result, RucioError};

/// Maximum accepted header block + body sizes (sanity bounds).
const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string, percent-decoded.
    pub path: String,
    /// Query parameters (later duplicates win).
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// Path placeholders filled in by the router (`{scope}` → value).
    pub params: BTreeMap<String, String>,
}

impl Request {
    pub fn new(method: &str, path: &str) -> Self {
        let (p, q) = split_query(path);
        Request {
            method: method.to_uppercase(),
            path: p,
            query: q,
            headers: BTreeMap::new(),
            body: Vec::new(),
            params: BTreeMap::new(),
        }
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    pub fn param(&self, name: &str) -> Result<&str> {
        self.params
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| RucioError::HttpError(format!("missing path param {name}")))
    }

    pub fn query_get(&self, name: &str) -> Option<&str> {
        self.query.get(name).map(|s| s.as_str())
    }

    pub fn body_json(&self) -> Result<crate::jsonx::Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| RucioError::JsonError("body is not utf-8".into()))?;
        crate::jsonx::Json::parse(text)
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Self {
        Response { status, headers: BTreeMap::new(), body: Vec::new() }
    }

    pub fn json(status: u16, v: &crate::jsonx::Json) -> Self {
        let mut r = Response::new(status);
        r.headers
            .insert("content-type".into(), "application/json".into());
        r.body = v.to_string().into_bytes();
        r
    }

    /// Newline-delimited JSON stream body (the paper's streamed list
    /// replies: "streaming the content of the replies can extend the total
    /// connection duration ... this does not block other clients").
    pub fn ndjson(status: u16, items: impl IntoIterator<Item = crate::jsonx::Json>) -> Self {
        let mut r = Response::new(status);
        r.headers
            .insert("content-type".into(), "application/x-ndjson".into());
        let mut body = String::new();
        for item in items {
            body.push_str(&item.to_string());
            body.push('\n');
        }
        r.body = body.into_bytes();
        r
    }

    pub fn text(status: u16, body: &str) -> Self {
        let mut r = Response::new(status);
        r.headers.insert("content-type".into(), "text/plain".into());
        r.body = body.as_bytes().to_vec();
        r
    }

    /// The unified REST error envelope: every error path answers
    /// `{"error": {"code": "<RucioError variant>", "message": "<detail>"}}`
    /// with the status from the single [`RucioError::http_status`]
    /// mapping — there is exactly one place errors turn into bodies.
    pub fn error(e: &RucioError) -> Self {
        let body = crate::jsonx::Json::obj().with(
            "error",
            crate::jsonx::Json::obj()
                .with("code", e.code())
                .with("message", format!("{e}")),
        );
        Response::json(e.http_status(), &body)
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Self {
        self.headers.insert(name.to_ascii_lowercase(), value.to_string());
        self
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    pub fn body_json(&self) -> Result<crate::jsonx::Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| RucioError::JsonError("body is not utf-8".into()))?;
        crate::jsonx::Json::parse(text)
    }

    /// Parse an NDJSON body into values.
    pub fn body_ndjson(&self) -> Result<Vec<crate::jsonx::Json>> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| RucioError::JsonError("body is not utf-8".into()))?;
        text.lines()
            .filter(|l| !l.trim().is_empty())
            .map(crate::jsonx::Json::parse)
            .collect()
    }

    pub fn ok(&self) -> bool {
        (200..300).contains(&self.status)
    }
}

fn split_query(path_and_query: &str) -> (String, BTreeMap<String, String>) {
    match path_and_query.split_once('?') {
        None => (percent_decode(path_and_query), BTreeMap::new()),
        Some((p, q)) => {
            let mut map = BTreeMap::new();
            for pair in q.split('&') {
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                map.insert(percent_decode(k), percent_decode(v));
            }
            (percent_decode(p), map)
        }
    }
}

/// Percent-decode a URL component (also turns `+` into space in queries —
/// we accept it everywhere for simplicity).
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() + 1 && i + 2 < bytes.len() + 1 => {
                if i + 2 < bytes.len() {
                    let hi = (bytes[i + 1] as char).to_digit(16);
                    let lo = (bytes[i + 2] as char).to_digit(16);
                    if let (Some(h), Some(l)) = (hi, lo) {
                        out.push((h * 16 + l) as u8);
                        i += 3;
                        continue;
                    }
                }
                out.push(b'%');
                i += 1;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Percent-encode a URL path segment.
pub fn percent_encode(s: &str) -> String {
    let mut out = String::new();
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// Read one HTTP request from a stream. Returns `Ok(None)` on clean EOF
/// (keep-alive connection closed by peer).
pub(crate) fn read_request<R: Read>(reader: &mut BufReader<R>) -> Result<Option<Request>> {
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    let mut parts = line.trim_end().split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| RucioError::HttpError("empty request line".into()))?;
    let target = parts
        .next()
        .ok_or_else(|| RucioError::HttpError("missing request target".into()))?;
    let _version = parts.next().unwrap_or("HTTP/1.1");

    let mut req = Request::new(method, target);
    let mut header_bytes = 0usize;
    loop {
        let mut hl = String::new();
        let n = reader.read_line(&mut hl)?;
        if n == 0 {
            return Err(RucioError::HttpError("eof in headers".into()));
        }
        header_bytes += n;
        if header_bytes > MAX_HEADER_BYTES {
            return Err(RucioError::HttpError("header block too large".into()));
        }
        let t = hl.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            req.headers
                .insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = req
        .header("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(RucioError::HttpError("body too large".into()));
    }
    if len > 0 {
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body)?;
        req.body = body;
    }
    Ok(Some(req))
}

pub(crate) fn write_response<W: Write>(w: &mut W, resp: &Response, keep_alive: bool) -> Result<()> {
    let reason = match resp.status {
        200 => "OK",
        201 => "Created",
        204 => "No Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    };
    let mut head = format!("HTTP/1.1 {} {}\r\n", resp.status, reason);
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("content-length: {}\r\n", resp.body.len()));
    head.push_str(if keep_alive {
        "connection: keep-alive\r\n"
    } else {
        "connection: close\r\n"
    });
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(&resp.body)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_parses_query_and_path() {
        let r = Request::new("get", "/dids/data18/list?limit=5&long=1&name=a%20b");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/dids/data18/list");
        assert_eq!(r.query_get("limit"), Some("5"));
        assert_eq!(r.query_get("name"), Some("a b"));
    }

    #[test]
    fn percent_round_trip() {
        let s = "user.alice:my analysis/v1+x";
        assert_eq!(percent_decode(&percent_encode(s)), s);
        assert_eq!(percent_decode("a%2Fb"), "a/b");
        assert_eq!(percent_decode("bad%zz"), "bad%zz");
    }

    #[test]
    fn read_request_round_trip() {
        let raw = b"POST /rules HTTP/1.1\r\ncontent-length: 7\r\nx-rucio-auth-token: tok\r\n\r\n{\"a\":1}";
        let mut reader = BufReader::new(&raw[..]);
        let req = read_request(&mut reader).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/rules");
        assert_eq!(req.header("x-rucio-auth-token"), Some("tok"));
        assert_eq!(req.body_json().unwrap().req_i64("a").unwrap(), 1);
    }

    #[test]
    fn read_request_eof_is_none() {
        let raw: &[u8] = b"";
        let mut reader = BufReader::new(raw);
        assert!(read_request(&mut reader).unwrap().is_none());
    }

    #[test]
    fn write_response_format() {
        let mut out = Vec::new();
        let resp = Response::text(200, "hello");
        write_response(&mut out, &resp, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("content-length: 5\r\n"));
        assert!(text.ends_with("\r\n\r\nhello"));
    }

    #[test]
    fn response_helpers() {
        let e = RucioError::DidNotFound("scope:name".into());
        let r = Response::error(&e);
        assert_eq!(r.status, 404);
        let body = r.body_json().unwrap();
        let env = body.get("error").expect("error envelope");
        assert_eq!(env.opt_str("code"), Some("DidNotFound"));
        assert!(env.opt_str("message").unwrap().contains("scope:name"));

        let nd = Response::ndjson(
            200,
            vec![crate::jsonx::Json::obj().with("i", 1), crate::jsonx::Json::obj().with("i", 2)],
        );
        let items = nd.body_ndjson().unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].req_i64("i").unwrap(), 2);
    }
}
