//! Path router with `{placeholder}` segments, shared by the REST server.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::{Request, Response};
use crate::common::error::RucioError;

/// A route handler. Receives the request with `params` filled in.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

struct Route {
    method: String,
    /// Split pattern segments; `{name}` binds one segment, `{name...}`
    /// binds the rest of the path (greedy tail — DID names contain `/`).
    segments: Vec<String>,
    handler: Handler,
}

/// Method+path dispatch table.
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add<F>(&mut self, method: &str, pattern: &str, handler: F)
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.routes.push(Route {
            method: method.to_uppercase(),
            segments: pattern
                .trim_matches('/')
                .split('/')
                .map(|s| s.to_string())
                .collect(),
            handler: Arc::new(handler),
        });
    }

    pub fn get<F>(&mut self, pattern: &str, h: F)
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.add("GET", pattern, h)
    }

    pub fn post<F>(&mut self, pattern: &str, h: F)
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.add("POST", pattern, h)
    }

    pub fn put<F>(&mut self, pattern: &str, h: F)
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.add("PUT", pattern, h)
    }

    pub fn delete<F>(&mut self, pattern: &str, h: F)
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.add("DELETE", pattern, h)
    }

    /// Dispatch a request: fills `req.params` from the matched pattern.
    /// 404 when no path matches, 405 when the path matches another method.
    pub fn dispatch(&self, mut req: Request) -> Response {
        let path_segs: Vec<&str> = req.path.trim_matches('/').split('/').collect();
        let mut path_matched = false;
        for route in &self.routes {
            if let Some(params) = match_segments(&route.segments, &path_segs) {
                path_matched = true;
                if route.method == req.method {
                    req.params = params;
                    return (route.handler)(&req);
                }
            }
        }
        // Unmatched requests answer with the same error envelope as the
        // route handlers: one body shape for every error on the surface.
        if path_matched {
            Response::error(&RucioError::MethodNotAllowed(format!(
                "{} {}",
                req.method, req.path
            )))
        } else {
            Response::error(&RucioError::RouteNotFound(req.path.clone()))
        }
    }
}

fn match_segments(pattern: &[String], path: &[&str]) -> Option<BTreeMap<String, String>> {
    let mut params = BTreeMap::new();
    let mut pi = 0;
    for (i, seg) in pattern.iter().enumerate() {
        if seg.starts_with('{') && seg.ends_with("...}") {
            // Greedy tail: bind the remaining path (must be non-empty).
            // Pattern segments after the tail (`/dids/{scope}/{name...}/rules`)
            // anchor at the end of the path; the tail binds what is between.
            let name = &seg[1..seg.len() - 4];
            let suffix = &pattern[i + 1..];
            if path.len() < pi + 1 + suffix.len() {
                return None;
            }
            let tail_end = path.len() - suffix.len();
            for (s, p) in suffix.iter().zip(&path[tail_end..]) {
                if s.starts_with('{') && s.ends_with('}') && !s.ends_with("...}") {
                    params.insert(s[1..s.len() - 1].to_string(), p.to_string());
                } else if s != p {
                    return None;
                }
            }
            params.insert(name.to_string(), path[pi..tail_end].join("/"));
            return Some(params);
        }
        if pi >= path.len() {
            return None;
        }
        if seg.starts_with('{') && seg.ends_with('}') {
            params.insert(seg[1..seg.len() - 1].to_string(), path[pi].to_string());
        } else if seg != path[pi] {
            return None;
        }
        pi += 1;
    }
    if pi == path.len() {
        Some(params)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(method: &str, path: &str) -> Request {
        Request::new(method, path)
    }

    fn router() -> Router {
        let mut r = Router::new();
        r.get("/ping", |_| Response::text(200, "pong"));
        r.get("/dids/{scope}/{name}", |rq| {
            Response::text(
                200,
                &format!("{}:{}", rq.params["scope"], rq.params["name"]),
            )
        });
        r.post("/dids/{scope}/{name}", |_| Response::text(201, "created"));
        r.get("/replicas/{scope}/{name...}", |rq| {
            Response::text(200, &rq.params["name"].clone())
        });
        r.get("/x/{scope}/{name...}/rules", |rq| {
            Response::text(200, &format!("rules:{}", rq.params["name"]))
        });
        r
    }

    #[test]
    fn static_route() {
        let r = router();
        let resp = r.dispatch(req("GET", "/ping"));
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"pong");
    }

    #[test]
    fn placeholder_binding() {
        let r = router();
        let resp = r.dispatch(req("GET", "/dids/data18/raw.001"));
        assert_eq!(resp.body, b"data18:raw.001");
    }

    #[test]
    fn greedy_tail_binds_slashes() {
        let r = router();
        let resp = r.dispatch(req("GET", "/replicas/user.alice/some/deep/name"));
        assert_eq!(resp.body, b"some/deep/name");
    }

    #[test]
    fn literal_suffix_after_greedy_tail_anchors_at_the_end() {
        let r = router();
        // single-segment name
        let resp = r.dispatch(req("GET", "/x/data18/raw.001/rules"));
        assert_eq!(resp.body, b"rules:raw.001");
        // slashed name keeps the suffix anchored at the path's end
        let resp = r.dispatch(req("GET", "/x/data18/a/b/c/rules"));
        assert_eq!(resp.body, b"rules:a/b/c");
        // no suffix → no match (the tail must leave room for it)
        assert_eq!(r.dispatch(req("GET", "/x/data18/raw.001")).status, 404);
    }

    #[test]
    fn wrong_method_is_405_missing_is_404() {
        let r = router();
        assert_eq!(r.dispatch(req("DELETE", "/ping")).status, 405);
        assert_eq!(r.dispatch(req("GET", "/nope")).status, 404);
        assert_eq!(r.dispatch(req("GET", "/dids/onlyscope")).status, 404);
    }

    #[test]
    fn method_dispatch_distinguishes() {
        let r = router();
        assert_eq!(r.dispatch(req("POST", "/dids/a/b")).status, 201);
        assert_eq!(r.dispatch(req("GET", "/dids/a/b")).status, 200);
    }
}
