//! Thread-pooled HTTP server: the "web server spawns multiple instances,
//! each controlling multiple WSGI containers" of paper §5.2, collapsed to
//! one process with N worker threads.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::{read_request, write_response, Response, Router};
use crate::common::error::Result;

/// A running HTTP server. Dropping it (or calling [`HttpServer::shutdown`])
/// stops the accept loop and joins the workers.
pub struct HttpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// Served request counter (the §5.3 interaction-rate metric source).
    pub requests_served: Arc<AtomicU64>,
}

impl HttpServer {
    /// Bind to `host:port` (port 0 picks a free port) and serve `router`
    /// with `n_workers` threads.
    pub fn start(bind: &str, router: Router, n_workers: usize) -> Result<HttpServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let router = Arc::new(router);
        let requests_served = Arc::new(AtomicU64::new(0));

        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let rx = rx.clone();
            let router = router.clone();
            let stop = stop.clone();
            let served = requests_served.clone();
            workers.push(std::thread::spawn(move || loop {
                let stream = {
                    let guard = rx.lock().unwrap();
                    guard.recv_timeout(Duration::from_millis(100))
                };
                match stream {
                    Ok(s) => handle_connection(s, &router, &served),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            }));
        }

        // Blocking accept: an idle server parks in the kernel instead of
        // polling. `shutdown` wakes the thread with a throwaway
        // connection after setting the stop flag.
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stop2.load(Ordering::Relaxed) {
                        return; // the shutdown wakeup (or a too-late client)
                    }
                    let _ = tx.send(stream);
                }
                Err(_) => {
                    if stop2.load(Ordering::Relaxed) {
                        return;
                    }
                    // Transient accept failure (e.g. ECONNABORTED):
                    // back off briefly rather than spin.
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        });

        Ok(HttpServer {
            addr,
            stop,
            accept_thread: Some(accept_thread),
            workers,
            requests_served,
        })
    }

    /// The bound address, e.g. `127.0.0.1:37211`.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            // Unblock the accept call so the thread sees the stop flag.
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(stream: TcpStream, router: &Router, served: &AtomicU64) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    // Nagle + delayed-ACK between the two response writes costs ~40 ms
    // per request without this (EXPERIMENTS.md §Perf step 3).
    let _ = stream.set_nodelay(true);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // Keep-alive loop: serve requests until the client closes or errors.
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return,
            Err(_) => {
                let _ = write_response(&mut writer, &Response::text(400, "bad request"), false);
                return;
            }
        };
        let keep_alive = req
            .header("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true);
        let resp = router.dispatch(req);
        served.fetch_add(1, Ordering::Relaxed);
        if write_response(&mut writer, &resp, keep_alive).is_err() || !keep_alive {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::httpd::HttpClient;
    use crate::jsonx::Json;

    fn test_server() -> HttpServer {
        let mut router = Router::new();
        router.get("/ping", |_| Response::text(200, "pong"));
        router.post("/echo", |req| {
            Response::new(200).with_header("content-type", "application/json").clone_body(req)
        });
        router.get("/item/{id}", |req| {
            Response::json(200, &Json::obj().with("id", req.params["id"].as_str()))
        });
        HttpServer::start("127.0.0.1:0", router, 4).unwrap()
    }

    impl Response {
        fn clone_body(mut self, req: &super::super::Request) -> Response {
            self.body = req.body.clone();
            self
        }
    }

    #[test]
    fn serves_basic_requests() {
        let server = test_server();
        let client = HttpClient::new(&server.url());
        let resp = client.get("/ping").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"pong");
    }

    #[test]
    fn serves_json_and_params() {
        let server = test_server();
        let client = HttpClient::new(&server.url());
        let resp = client.get("/item/42").unwrap();
        assert_eq!(resp.body_json().unwrap().req_str("id").unwrap(), "42");

        let resp = client
            .post_json("/echo", &Json::obj().with("hello", "world"))
            .unwrap();
        assert_eq!(resp.body_json().unwrap().req_str("hello").unwrap(), "world");
    }

    #[test]
    fn keep_alive_reuses_connection() {
        let server = test_server();
        let client = HttpClient::new(&server.url());
        for _ in 0..10 {
            assert_eq!(client.get("/ping").unwrap().status, 200);
        }
        assert_eq!(server.requests_served.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn concurrent_clients() {
        let server = test_server();
        let url = server.url();
        let mut handles = vec![];
        for _ in 0..8 {
            let url = url.clone();
            handles.push(std::thread::spawn(move || {
                let client = HttpClient::new(&url);
                for _ in 0..20 {
                    assert_eq!(client.get("/ping").unwrap().status, 200);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.requests_served.load(Ordering::Relaxed), 160);
    }

    #[test]
    fn shutdown_wakes_blocking_accept_promptly() {
        let mut server = test_server();
        // No client ever connects: the accept thread is parked in the
        // kernel and must be woken by shutdown's throwaway connection.
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5), "shutdown hung on accept");
        // idempotent
        server.shutdown();
    }

    #[test]
    fn unknown_route_404s() {
        let server = test_server();
        let client = HttpClient::new(&server.url());
        assert_eq!(client.get("/nope").unwrap().status, 404);
    }
}
