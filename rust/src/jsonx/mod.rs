//! Minimal JSON implementation (value model, serializer, recursive-descent
//! parser). Stands in for `serde_json`, which is unavailable offline.
//!
//! Used by: the REST server/client payloads, hermes message payloads
//! (paper §4.5: "The payload is always schema-free JSON"), config dumps,
//! and analytics reports.

use std::collections::BTreeMap;
use std::fmt;

use crate::common::error::{Result, RucioError};

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — handy for golden tests and reproducible reports.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All numbers are carried as f64 (JSON semantics); integer accessors
    /// round-trip exactly for |n| <= 2^53.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; no-op with a debug assert on non-objects.
    pub fn with(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(m) = &mut self {
            m.insert(key.to_string(), value.into());
        } else {
            debug_assert!(false, "Json::with on non-object");
        }
        self
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value.into());
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|n| u64::try_from(n).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed field access with crate errors — the REST layer's input
    /// validation path.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| RucioError::JsonError(format!("missing string field '{key}'")))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| RucioError::JsonError(format!("missing unsigned field '{key}'")))
    }

    pub fn req_i64(&self, key: &str) -> Result<i64> {
        self.get(key)
            .and_then(Json::as_i64)
            .ok_or_else(|| RucioError::JsonError(format!("missing integer field '{key}'")))
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    pub fn opt_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(Json::as_u64)
    }

    pub fn opt_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Json::as_i64)
    }

    pub fn opt_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Json::as_bool)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out);
        out
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(RucioError::JsonError(format!(
                "trailing garbage at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Json {
        Json::Num(n as f64)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Option<T>> for Json {
    fn from(v: Option<T>) -> Json {
        match v {
            Some(x) => x.into(),
            None => Json::Null,
        }
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e18 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> RucioError {
        RucioError::JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00));
                                    out.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate pair"))?,
                                    );
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 multi-byte sequences from the raw input.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    if len == 1 {
                        out.push(b as char);
                    } else {
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::forall;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_round_trip() {
        let s = "quote\" back\\ nl\n tab\t unicode ü 日本 emoji 🎉";
        let j = Json::Str(s.into());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn unicode_escape_forms() {
        assert_eq!(Json::parse(r#""ü""#).unwrap().as_str(), Some("ü"));
        // surrogate pair: 🎉 = U+1F389
        assert_eq!(Json::parse(r#""🎉""#).unwrap().as_str(), Some("🎉"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_preserved_exactly() {
        let j = Json::from(9_007_199_254_740_992u64); // 2^53
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_u64(), Some(9_007_199_254_740_992));
    }

    #[test]
    fn builder_and_accessors() {
        let j = Json::obj()
            .with("name", "dataset1")
            .with("bytes", 1234u64)
            .with("open", true)
            .with("tags", vec!["a", "b"]);
        assert_eq!(j.req_str("name").unwrap(), "dataset1");
        assert_eq!(j.req_u64("bytes").unwrap(), 1234);
        assert_eq!(j.opt_bool("open"), Some(true));
        assert!(j.req_str("missing").is_err());
        assert_eq!(j.get("tags").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn object_key_order_is_stable() {
        let a = Json::obj().with("z", 1).with("a", 2);
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn prop_round_trip_random_documents() {
        forall(150, |g| {
            let doc = random_json(g, 3);
            let text = doc.to_string();
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("{e}: {text}"));
            assert_eq!(back, doc, "round-trip mismatch for {text}");
        });
    }

    fn random_json(g: &mut crate::common::proptest::Gen, depth: usize) -> Json {
        let choice = if depth == 0 { g.usize(0, 4) } else { g.usize(0, 6) };
        match choice {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num(g.i64(-1_000_000, 1_000_000) as f64),
            3 => Json::Str(g.string(0..20)),
            4 => {
                let n = g.usize(0, 5);
                Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
            }
            _ => {
                let n = g.usize(0, 5);
                let mut m = BTreeMap::new();
                for _ in 0..n {
                    m.insert(g.ident(1..10), random_json(g, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
}
