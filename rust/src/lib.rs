//! # rucio-rs — a Rust + JAX/Pallas reproduction of *Rucio — Scientific data management*
//!
//! This crate implements the full Rucio system described in Barisits et al.,
//! Computing and Software for Big Science (2019), DOI 10.1007/s41781-019-0026-3,
//! on top of simulated grid infrastructure (storage, network, FTS), with the
//! paper's §6 numeric decision models (dynamic placement scoring, transfer-time
//! prediction) AOT-compiled from JAX/Pallas and executed through PJRT.
//!
//! Layering (see DESIGN.md):
//! * substrates: [`common`], [`jsonx`], [`db`], [`httpd`], [`mq`], [`netsim`],
//!   [`storagesim`], [`ftssim`], [`benchkit`]
//! * core concepts (paper §2): [`core`]
//! * daemons (paper §3.4/§4): [`daemons`]
//! * server + clients (paper §3.2/§3.3): [`server`], [`client`]
//! * §6 advanced features: [`placement`], [`rebalance`], [`t3c`], backed by
//!   [`runtime`] (PJRT artifact execution)
//! * simulation + analytics: [`sim`], [`analytics`]

pub mod common;
pub mod jsonx;
pub mod db;
pub mod httpd;
pub mod mq;
pub mod netsim;
pub mod storagesim;
pub mod ftssim;
pub mod benchkit;
pub mod core;
pub mod daemons;
pub mod runtime;
pub mod placement;
pub mod rebalance;
pub mod t3c;
pub mod server;
pub mod client;
pub mod sim;
pub mod analytics;

pub use common::error::{Result, RucioError};
