//! `rucio` — the leader binary: run the REST server + daemon fleet, run
//! simulation scenarios, or act as a CLI client (paper §3.2's bin/rucio
//! and bin/rucio-admin collapsed into subcommands).

use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::common::units::fmt_bytes;
use rucio::sim::driver::{standard_driver, Driver};
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;

fn main() {
    rucio::common::logx::init(1);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "serve" => serve(&flags),
        "sim" => sim(&flags),
        "ping" => client_ping(&flags),
        "stats" => client_stats(&flags),
        _ => help(),
    }
}

fn parse_flags(args: &[String]) -> std::collections::BTreeMap<String, String> {
    let mut map = std::collections::BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let value = args.get(i + 1).cloned().unwrap_or_else(|| "true".into());
            map.insert(key.to_string(), value);
            i += 2;
        } else {
            i += 1;
        }
    }
    map
}

fn help() {
    println!(
        r#"rucio-rs — Rucio scientific data management (paper reproduction)

USAGE:
  rucio serve [--bind 127.0.0.1:8080] [--workers 8] [--config rucio.cfg]
      run the REST server + full daemon fleet on a simulated grid
  rucio sim [--days 30] [--tick-min 10] [--t2 2] [--report out.csv]
      run the discrete-event simulation and print daily stats
  rucio ping [--url http://127.0.0.1:8080]
  rucio stats [--days ...]   alias of sim with a summary table
"#
    );
}

fn load_config(flags: &std::collections::BTreeMap<String, String>) -> Config {
    match flags.get("config") {
        Some(path) => Config::from_file(path).expect("config parse error"),
        None => Config::new(),
    }
}

/// Production-style mode: real clock, REST server + threaded daemons.
fn serve(flags: &std::collections::BTreeMap<String, String>) {
    let bind = flags.get("bind").map(|s| s.as_str()).unwrap_or("127.0.0.1:8080");
    let workers: usize = flags.get("workers").and_then(|s| s.parse().ok()).unwrap_or(8);
    let cfg = load_config(flags);
    let ctx = rucio::sim::grid::build_grid(&GridSpec::default(), Clock::real(), cfg);
    // default userpass identities for interactive use
    ctx.catalog
        .add_identity("root", rucio::core::types::AuthType::UserPass, "root", Some("root"))
        .ok();
    let server = rucio::server::serve(ctx.catalog.clone(), ctx.broker.clone(), bind, workers)
        .expect("bind failed");
    println!("rucio server listening on {}", server.url());
    let fleet = rucio::daemons::FleetHandle::spawn(Driver::standard_daemons(&ctx));
    println!("{} daemons running; Ctrl-C to stop", fleet.len());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn sim(flags: &std::collections::BTreeMap<String, String>) {
    let days: u32 = flags.get("days").and_then(|s| s.parse().ok()).unwrap_or(30);
    let tick_min: i64 = flags.get("tick-min").and_then(|s| s.parse().ok()).unwrap_or(10);
    let t2: usize = flags.get("t2").and_then(|s| s.parse().ok()).unwrap_or(2);
    let cfg = load_config(flags);
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: t2, ..Default::default() },
        WorkloadSpec::default(),
        cfg,
    );
    let t0 = std::time::Instant::now();
    driver.run_days(days, tick_min * MINUTE_MS);
    println!(
        "simulated {days} days in {:.1}s wall-clock",
        t0.elapsed().as_secs_f64()
    );
    println!("\nday  volume-managed  transferred  done  failed  deletions");
    for d in &driver.days {
        println!(
            "{:>3}  {:>14}  {:>11}  {:>5}  {:>6}  {:>9}",
            d.day,
            fmt_bytes(d.bytes_managed),
            fmt_bytes(d.bytes_transferred),
            d.transfers_done,
            d.transfers_failed,
            d.deletions
        );
    }
    if let Some(path) = flags.get("report") {
        let rows: Vec<Vec<String>> = driver
            .days
            .iter()
            .map(|d| {
                vec![
                    d.day.to_string(),
                    d.bytes_managed.to_string(),
                    d.bytes_transferred.to_string(),
                    d.transfers_done.to_string(),
                    d.transfers_failed.to_string(),
                    d.deletions.to_string(),
                ]
            })
            .collect();
        let csv = rucio::analytics::reports::to_csv(
            &["day", "bytes_managed", "bytes_transferred", "done", "failed", "deletions"],
            &rows,
        );
        std::fs::write(path, csv).expect("write report");
        println!("wrote {path}");
    }
}

fn client_ping(flags: &std::collections::BTreeMap<String, String>) {
    let url = flags.get("url").map(|s| s.as_str()).unwrap_or("http://127.0.0.1:8080");
    let http = rucio::httpd::HttpClient::new(url);
    match http.get("/ping") {
        Ok(resp) => println!("{}", String::from_utf8_lossy(&resp.body)),
        Err(e) => {
            eprintln!("ping failed: {e}");
            std::process::exit(1);
        }
    }
}

fn client_stats(flags: &std::collections::BTreeMap<String, String>) {
    sim(flags)
}
