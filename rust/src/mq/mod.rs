//! In-process STOMP-style message broker — the ActiveMQ substitute
//! (paper §4.5: "Rucio supports STOMP protocol compatible queuing
//! services"; §4.6: traces/events fan out through topics into per-consumer
//! queues).
//!
//! Semantics implemented:
//! * **topics** — publish/subscribe: every subscriber's queue receives a
//!   copy of each message published after it subscribed;
//! * **queues** — point-to-point: competing consumers, each message
//!   delivered to exactly one consumer;
//! * event-type **filters** on subscriptions (the "event-type can be used
//!   by queue listeners to filter for messages" of §4.5);
//! * bounded queues with drop-oldest overflow (a real broker's TTL stand-in)
//!   plus drop counters for monitoring.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::common::clock::EpochMs;
use crate::jsonx::Json;

/// A broker message: event type + schema-free JSON payload (paper §4.5).
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub event_type: String,
    pub payload: Json,
    pub created_at: EpochMs,
}

impl Message {
    pub fn new(event_type: &str, payload: Json, now: EpochMs) -> Self {
        Message { event_type: event_type.to_string(), payload, created_at: now }
    }
}

#[derive(Debug, Default)]
struct SubQueue {
    buf: VecDeque<Message>,
    filter: Option<String>,
    dropped: u64,
}

#[derive(Debug, Default)]
struct TopicState {
    subs: BTreeMap<u64, SubQueue>,
}

#[derive(Debug, Default)]
struct QueueState {
    buf: VecDeque<Message>,
    dropped: u64,
}

#[derive(Debug, Default)]
struct BrokerInner {
    topics: BTreeMap<String, TopicState>,
    queues: BTreeMap<String, QueueState>,
    next_sub: u64,
    capacity: usize,
    published: u64,
}

/// The broker handle (cheap to clone; all clones share state).
#[derive(Clone)]
pub struct Broker {
    inner: Arc<Mutex<BrokerInner>>,
}

/// A topic subscription handle; poll with [`Broker::poll`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubId {
    topic_hash: u64,
    id: u64,
}

const DEFAULT_CAPACITY: usize = 100_000;

impl Default for Broker {
    fn default() -> Self {
        Self::new()
    }
}

impl Broker {
    pub fn new() -> Self {
        Broker {
            inner: Arc::new(Mutex::new(BrokerInner {
                capacity: DEFAULT_CAPACITY,
                ..Default::default()
            })),
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        let b = Broker::new();
        b.inner.lock().unwrap().capacity = cap;
        b
    }

    /// Subscribe to a topic, optionally filtering on an event type.
    pub fn subscribe(&self, topic: &str, filter: Option<&str>) -> SubId {
        let mut inner = self.inner.lock().unwrap();
        inner.next_sub += 1;
        let id = inner.next_sub;
        let t = inner.topics.entry(topic.to_string()).or_default();
        t.subs.insert(
            id,
            SubQueue { buf: VecDeque::new(), filter: filter.map(|s| s.to_string()), dropped: 0 },
        );
        SubId { topic_hash: crate::db::shard_hash(topic.as_bytes()), id }
    }

    /// Publish to a topic: fanned out to all (matching) subscribers.
    pub fn publish(&self, topic: &str, msg: Message) {
        let mut inner = self.inner.lock().unwrap();
        inner.published += 1;
        let cap = inner.capacity;
        if let Some(t) = inner.topics.get_mut(topic) {
            for sub in t.subs.values_mut() {
                if let Some(f) = &sub.filter {
                    if f != &msg.event_type {
                        continue;
                    }
                }
                sub.buf.push_back(msg.clone());
                if sub.buf.len() > cap {
                    sub.buf.pop_front();
                    sub.dropped += 1;
                }
            }
        }
    }

    /// Drain up to `max` messages from a topic subscription.
    pub fn poll(&self, topic: &str, sub: SubId, max: usize) -> Vec<Message> {
        let mut inner = self.inner.lock().unwrap();
        let Some(t) = inner.topics.get_mut(topic) else {
            return Vec::new();
        };
        let Some(q) = t.subs.get_mut(&sub.id) else {
            return Vec::new();
        };
        let n = max.min(q.buf.len());
        q.buf.drain(..n).collect()
    }

    pub fn unsubscribe(&self, topic: &str, sub: SubId) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(t) = inner.topics.get_mut(topic) {
            t.subs.remove(&sub.id);
        }
    }

    /// Point-to-point send (named queue, competing consumers).
    pub fn send(&self, queue: &str, msg: Message) {
        let mut inner = self.inner.lock().unwrap();
        inner.published += 1;
        let cap = inner.capacity;
        let q = inner.queues.entry(queue.to_string()).or_default();
        q.buf.push_back(msg);
        if q.buf.len() > cap {
            q.buf.pop_front();
            q.dropped += 1;
        }
    }

    /// Competing-consumer receive: up to `max` messages, each delivered once.
    pub fn receive(&self, queue: &str, max: usize) -> Vec<Message> {
        let mut inner = self.inner.lock().unwrap();
        let Some(q) = inner.queues.get_mut(queue) else {
            return Vec::new();
        };
        let n = max.min(q.buf.len());
        q.buf.drain(..n).collect()
    }

    /// Queue depth (monitoring probe surface).
    pub fn queue_depth(&self, queue: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .queues
            .get(queue)
            .map(|q| q.buf.len())
            .unwrap_or(0)
    }

    pub fn topic_depth(&self, topic: &str, sub: SubId) -> usize {
        self.inner
            .lock()
            .unwrap()
            .topics
            .get(topic)
            .and_then(|t| t.subs.get(&sub.id))
            .map(|q| q.buf.len())
            .unwrap_or(0)
    }

    pub fn total_published(&self) -> u64 {
        self.inner.lock().unwrap().published
    }

    pub fn total_dropped(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.queues.values().map(|q| q.dropped).sum::<u64>()
            + inner
                .topics
                .values()
                .flat_map(|t| t.subs.values().map(|s| s.dropped))
                .sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(event: &str, i: i64) -> Message {
        Message::new(event, Json::obj().with("i", i), i)
    }

    #[test]
    fn topic_fans_out_to_all_subscribers() {
        let b = Broker::new();
        let s1 = b.subscribe("events", None);
        let s2 = b.subscribe("events", None);
        b.publish("events", msg("transfer-done", 1));
        assert_eq!(b.poll("events", s1, 10).len(), 1);
        assert_eq!(b.poll("events", s2, 10).len(), 1);
        // Polling again yields nothing.
        assert_eq!(b.poll("events", s1, 10).len(), 0);
    }

    #[test]
    fn subscription_starts_empty() {
        let b = Broker::new();
        b.publish("events", msg("transfer-done", 1));
        let late = b.subscribe("events", None);
        assert_eq!(b.poll("events", late, 10).len(), 0);
    }

    #[test]
    fn event_type_filter_applies() {
        let b = Broker::new();
        let s = b.subscribe("events", Some("deletion-done"));
        b.publish("events", msg("transfer-done", 1));
        b.publish("events", msg("deletion-done", 2));
        let got = b.poll("events", s, 10);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].event_type, "deletion-done");
    }

    #[test]
    fn queue_delivers_each_message_once() {
        let b = Broker::new();
        for i in 0..10 {
            b.send("work", msg("job", i));
        }
        let a = b.receive("work", 6);
        let c = b.receive("work", 6);
        assert_eq!(a.len(), 6);
        assert_eq!(c.len(), 4);
        assert_eq!(b.receive("work", 6).len(), 0);
    }

    #[test]
    fn overflow_drops_oldest() {
        let b = Broker::with_capacity(3);
        for i in 0..5 {
            b.send("q", msg("e", i));
        }
        let got = b.receive("q", 10);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].payload.req_i64("i").unwrap(), 2);
        assert_eq!(b.total_dropped(), 2);
    }

    #[test]
    fn depths_and_counters() {
        let b = Broker::new();
        let s = b.subscribe("t", None);
        b.publish("t", msg("e", 1));
        b.send("q", msg("e", 2));
        assert_eq!(b.topic_depth("t", s), 1);
        assert_eq!(b.queue_depth("q"), 1);
        assert_eq!(b.total_published(), 2);
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let b = Broker::new();
        let s = b.subscribe("t", None);
        b.unsubscribe("t", s);
        b.publish("t", msg("e", 1));
        assert_eq!(b.poll("t", s, 10).len(), 0);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let b = Broker::new();
        let mut handles = vec![];
        for w in 0..4 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..250 {
                    b.send("work", msg("job", (w * 1000 + i) as i64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut total = 0;
        while !b.receive("work", 100).is_empty() {
            total += 100.min(1000 - total);
            if total >= 1000 {
                break;
            }
        }
        assert_eq!(b.queue_depth("work"), 0);
    }
}
