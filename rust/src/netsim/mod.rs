//! Network simulator — the NREN/LHCOPN/LHCONE substitute (paper §1.3).
//!
//! Model: sites (data centres) connected by directed links with a
//! bandwidth, a latency, and a *quality* (per-transfer success
//! probability — standing in for the storage/network configuration
//! problems that cause the paper's ~10–20 % failure rates and the Fig 8
//! efficiency structure). Unknown pairs fall back to a configurable
//! commodity-internet default link.
//!
//! Concurrent transfers on a link share its bandwidth equally (fair-share
//! approximation of TCP on a bottleneck); the FTS simulator integrates
//! progress over virtual time through [`Network::share_bps`].

use std::collections::BTreeMap;
use std::sync::{Mutex, RwLock};

use crate::common::units::GB;

/// Identifies a site (data centre). RSEs map to sites in their attributes.
pub type Site = String;

/// A directed network link.
#[derive(Debug, Clone)]
pub struct Link {
    /// Capacity in bytes/second.
    pub bandwidth_bps: u64,
    /// One-way latency in milliseconds (adds transfer startup cost).
    pub latency_ms: i64,
    /// Probability a single transfer over this link succeeds.
    pub quality: f64,
}

impl Link {
    pub fn new(bandwidth_bps: u64, latency_ms: i64, quality: f64) -> Self {
        Link { bandwidth_bps, latency_ms, quality: quality.clamp(0.0, 1.0) }
    }

    /// A 100 Gbps LHCOPN-class link.
    pub fn lhcopn() -> Self {
        Link::new(100 * GB / 8, 15, 0.98)
    }

    /// A 40 Gbps institute link.
    pub fn institute() -> Self {
        Link::new(40 * GB / 8, 30, 0.95)
    }

    /// Commodity-internet fallback (paper §1.3: "Traffic can also be routed
    /// over the commodity internet as a fallback").
    pub fn commodity() -> Self {
        Link::new(10 * GB / 8, 80, 0.90)
    }
}

#[derive(Debug, Default)]
struct LoadState {
    /// Active transfer count per directed pair.
    active: BTreeMap<(Site, Site), usize>,
}

/// A runtime fault overlaid on a link without touching its nominal
/// parameters (chaos scenarios: degradation, partition). Multiplies the
/// link quality and divides its bandwidth; `quality_mult = 0` is a full
/// partition. Clearing the fault restores the nominal link exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFault {
    pub quality_mult: f64,
    pub bandwidth_div: u64,
}

impl LinkFault {
    /// Degraded link: quality scaled down, bandwidth divided.
    pub fn degraded(quality_mult: f64, bandwidth_div: u64) -> Self {
        LinkFault { quality_mult: quality_mult.clamp(0.0, 1.0), bandwidth_div: bandwidth_div.max(1) }
    }

    /// Full partition: nothing gets through.
    pub fn partition() -> Self {
        LinkFault { quality_mult: 0.0, bandwidth_div: 1 }
    }
}

/// The network: link table + live load tracking + transfer telemetry used
/// for dynamic distance re-evaluation (paper §2.4).
pub struct Network {
    links: RwLock<BTreeMap<(Site, Site), Link>>,
    default_link: RwLock<Link>,
    /// Active fault overlay per directed pair (chaos scenarios).
    faults: RwLock<BTreeMap<(Site, Site), LinkFault>>,
    load: Mutex<LoadState>,
    /// Exponentially-weighted achieved throughput per pair (bytes/s),
    /// updated on transfer completion — the "periodic re-evaluation of the
    /// collected average throughput" signal.
    ewma_bps: Mutex<BTreeMap<(Site, Site), f64>>,
}

impl Default for Network {
    fn default() -> Self {
        Self::new()
    }
}

impl Network {
    pub fn new() -> Self {
        Network {
            links: RwLock::new(BTreeMap::new()),
            default_link: RwLock::new(Link::commodity()),
            faults: RwLock::new(BTreeMap::new()),
            load: Mutex::new(LoadState::default()),
            ewma_bps: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn set_link(&self, src: &str, dst: &str, link: Link) {
        self.links
            .write()
            .unwrap()
            .insert((src.to_string(), dst.to_string()), link);
    }

    /// Symmetric convenience.
    pub fn set_link_bidir(&self, a: &str, b: &str, link: Link) {
        self.set_link(a, b, link.clone());
        self.set_link(b, a, link);
    }

    pub fn set_default_link(&self, link: Link) {
        *self.default_link.write().unwrap() = link;
    }

    pub fn link(&self, src: &str, dst: &str) -> Link {
        let key = (src.to_string(), dst.to_string());
        let nominal = self
            .links
            .read()
            .unwrap()
            .get(&key)
            .cloned()
            .unwrap_or_else(|| self.default_link.read().unwrap().clone());
        match self.faults.read().unwrap().get(&key) {
            Some(f) => Link::new(
                (nominal.bandwidth_bps / f.bandwidth_div.max(1)).max(1),
                nominal.latency_ms,
                nominal.quality * f.quality_mult,
            ),
            None => nominal,
        }
    }

    /// Overlay a fault on a directed pair (degradation or partition).
    pub fn set_fault(&self, src: &str, dst: &str, fault: LinkFault) {
        self.faults
            .write()
            .unwrap()
            .insert((src.to_string(), dst.to_string()), fault);
    }

    /// Symmetric fault convenience.
    pub fn set_fault_bidir(&self, a: &str, b: &str, fault: LinkFault) {
        self.set_fault(a, b, fault);
        self.set_fault(b, a, fault);
    }

    /// Remove the fault on a directed pair; the nominal link returns.
    pub fn clear_fault(&self, src: &str, dst: &str) {
        self.faults
            .write()
            .unwrap()
            .remove(&(src.to_string(), dst.to_string()));
    }

    pub fn clear_fault_bidir(&self, a: &str, b: &str) {
        self.clear_fault(a, b);
        self.clear_fault(b, a);
    }

    /// Number of directed pairs currently under a fault overlay.
    pub fn fault_count(&self) -> usize {
        self.faults.read().unwrap().len()
    }

    /// Can a transfer succeed on this pair at all right now? A quality
    /// of zero (full partition, or an explicit dead link) means no —
    /// the conveyor's source ranking and the multi-hop path planner
    /// route around such pairs instead of burning retries on them.
    pub fn usable(&self, src: &str, dst: &str) -> bool {
        self.link(src, dst).quality > 0.0
    }

    /// Register a transfer starting on a pair (affects fair-share).
    pub fn acquire(&self, src: &str, dst: &str) {
        *self
            .load
            .lock()
            .unwrap()
            .active
            .entry((src.to_string(), dst.to_string()))
            .or_insert(0) += 1;
    }

    /// Transfer finished (success or failure) — release the slot.
    pub fn release(&self, src: &str, dst: &str) {
        let mut load = self.load.lock().unwrap();
        let key = (src.to_string(), dst.to_string());
        if let Some(n) = load.active.get_mut(&key) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                load.active.remove(&key);
            }
        }
    }

    pub fn active_on(&self, src: &str, dst: &str) -> usize {
        self.load
            .lock()
            .unwrap()
            .active
            .get(&(src.to_string(), dst.to_string()))
            .copied()
            .unwrap_or(0)
    }

    /// Current fair-share bandwidth (bytes/s) one transfer gets on a pair.
    pub fn share_bps(&self, src: &str, dst: &str) -> u64 {
        let link = self.link(src, dst);
        let n = self.active_on(src, dst).max(1) as u64;
        (link.bandwidth_bps / n).max(1)
    }

    /// Record achieved throughput of a completed transfer; feeds distance
    /// re-evaluation (EWMA with alpha = 0.2).
    pub fn record_throughput(&self, src: &str, dst: &str, bps: f64) {
        let mut ewma = self.ewma_bps.lock().unwrap();
        let key = (src.to_string(), dst.to_string());
        let entry = ewma.entry(key).or_insert(bps);
        *entry = 0.8 * *entry + 0.2 * bps;
    }

    /// Observed average throughput (bytes/s), if any transfers completed.
    pub fn observed_bps(&self, src: &str, dst: &str) -> Option<f64> {
        self.ewma_bps
            .lock()
            .unwrap()
            .get(&(src.to_string(), dst.to_string()))
            .copied()
    }

    /// All pairs with observed throughput (for the distance daemon sweep).
    pub fn observed_pairs(&self) -> Vec<(Site, Site, f64)> {
        self.ewma_bps
            .lock()
            .unwrap()
            .iter()
            .map(|((s, d), bps)| (s.clone(), d.clone(), *bps))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_link_for_unknown_pairs() {
        let net = Network::new();
        let l = net.link("X", "Y");
        assert_eq!(l.bandwidth_bps, Link::commodity().bandwidth_bps);
        net.set_link("X", "Y", Link::lhcopn());
        assert_eq!(net.link("X", "Y").bandwidth_bps, Link::lhcopn().bandwidth_bps);
        // direction matters
        assert_eq!(net.link("Y", "X").bandwidth_bps, Link::commodity().bandwidth_bps);
    }

    #[test]
    fn fair_share_divides_bandwidth() {
        let net = Network::new();
        net.set_link("A", "B", Link::new(1000, 1, 1.0));
        assert_eq!(net.share_bps("A", "B"), 1000);
        net.acquire("A", "B");
        net.acquire("A", "B");
        assert_eq!(net.active_on("A", "B"), 2);
        assert_eq!(net.share_bps("A", "B"), 500);
        net.release("A", "B");
        assert_eq!(net.share_bps("A", "B"), 1000);
        net.release("A", "B");
        net.release("A", "B"); // over-release is safe
        assert_eq!(net.active_on("A", "B"), 0);
    }

    #[test]
    fn throughput_ewma_converges() {
        let net = Network::new();
        assert!(net.observed_bps("A", "B").is_none());
        for _ in 0..60 {
            net.record_throughput("A", "B", 100.0);
        }
        let v = net.observed_bps("A", "B").unwrap();
        assert!((v - 100.0).abs() < 1.0);
        for _ in 0..60 {
            net.record_throughput("A", "B", 50.0);
        }
        let v = net.observed_bps("A", "B").unwrap();
        assert!((v - 50.0).abs() < 1.0, "v={v}");
    }

    #[test]
    fn bidir_sets_both_directions() {
        let net = Network::new();
        net.set_link_bidir("A", "B", Link::institute());
        assert_eq!(net.link("A", "B").latency_ms, 30);
        assert_eq!(net.link("B", "A").latency_ms, 30);
        assert_eq!(net.observed_pairs().len(), 0);
    }

    #[test]
    fn quality_clamped() {
        let l = Link::new(1, 1, 7.3);
        assert_eq!(l.quality, 1.0);
    }

    #[test]
    fn fault_overlay_degrades_and_restores() {
        let net = Network::new();
        net.set_link("A", "B", Link::new(1000, 5, 0.9));
        net.set_fault("A", "B", LinkFault::degraded(0.5, 4));
        let l = net.link("A", "B");
        assert_eq!(l.bandwidth_bps, 250);
        assert!((l.quality - 0.45).abs() < 1e-12);
        assert_eq!(l.latency_ms, 5);
        assert_eq!(net.fault_count(), 1);
        net.clear_fault("A", "B");
        let l = net.link("A", "B");
        assert_eq!(l.bandwidth_bps, 1000);
        assert!((l.quality - 0.9).abs() < 1e-12);
        assert_eq!(net.fault_count(), 0);
    }

    #[test]
    fn partition_zeroes_quality_both_ways() {
        let net = Network::new();
        net.set_link_bidir("A", "B", Link::new(1000, 5, 1.0));
        net.set_fault_bidir("A", "B", LinkFault::partition());
        assert_eq!(net.link("A", "B").quality, 0.0);
        assert_eq!(net.link("B", "A").quality, 0.0);
        assert!(!net.usable("A", "B"));
        assert!(net.usable("A", "C"), "default link is usable");
        // bandwidth floor keeps the share computation finite
        assert!(net.link("A", "B").bandwidth_bps >= 1);
        net.clear_fault_bidir("A", "B");
        assert_eq!(net.link("A", "B").quality, 1.0);
    }

    #[test]
    fn fault_applies_to_default_link_pairs_too() {
        let net = Network::new();
        net.set_fault("X", "Y", LinkFault::degraded(0.0, 1));
        assert_eq!(net.link("X", "Y").quality, 0.0);
    }
}
