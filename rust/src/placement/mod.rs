//! C3PO — dynamic data placement (paper §6.1): "dynamic data placement
//! helps to exploit computing and storage resources by ... creating
//! additional replicas of popular [datasets] at different RSEs".
//!
//! The algorithm follows the paper's description: scan incoming access
//! pressure (popularity from traces, standing in for the PanDA queued-job
//! signal), check recent-placement cool-down and the existing replica
//! count, then weigh candidate RSEs by free space, network connectivity,
//! queued files, and recent placements — and create a replication rule
//! for the winner. Scoring runs through the AOT-compiled Pallas kernel
//! ([`crate::runtime::Runtime::placement_score`]); a pure-Rust
//! [`RefScorer`] covers artifact-less tests and the ablation bench.

use std::collections::BTreeMap;

use crate::common::clock::{DAY_MS, EpochMs};
use crate::common::error::Result;
use crate::common::units::GB;
use crate::core::rules_api::RuleSpec;
use crate::core::types::{DidKey, DidType, RequestState};
use crate::jsonx::Json;
use crate::runtime::{ref_placement_score, Runtime};

use crate::daemons::{Ctx, Daemon};

/// Shared feature dimension (must equal `python/compile/kernels/score.py`).
pub const N_FEATURES: usize = 8;

/// Rule activity tag on every replica the placement loop creates. The
/// cache contract hangs off it: rules with this activity always carry a
/// lifetime (checked by `sim::invariants`), so the reaper reclaims cold
/// caches once the heat passes.
pub const CACHE_ACTIVITY: &str = "Dynamic Placement";

/// Default scoring weights: free space and closeness dominate; queue
/// depth, recent placements, and link load repel.
pub const DEFAULT_WEIGHTS: [f32; N_FEATURES] = [2.0, 1.0, -1.0, -0.5, 0.3, 1.5, -0.5, 0.0];

/// Scoring backend.
pub trait Scorer: Send {
    fn score(&mut self, features: &[f32], weights: &[f32], mask: &[f32])
        -> Result<(Vec<f32>, Vec<f32>)>;
    fn name(&self) -> &'static str;
}

/// Pure-Rust scorer (mirror of the Pallas kernel's oracle).
pub struct RefScorer;

impl Scorer for RefScorer {
    fn score(
        &mut self,
        features: &[f32],
        weights: &[f32],
        mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        Ok(ref_placement_score(features, weights, mask))
    }
    fn name(&self) -> &'static str {
        "ref"
    }
}

/// PJRT-backed scorer executing the Pallas artifact.
pub struct PjrtScorer {
    pub rt: Runtime,
}

impl PjrtScorer {
    pub fn load_default() -> Result<Self> {
        Ok(PjrtScorer { rt: Runtime::load_default()? })
    }
}

impl Scorer for PjrtScorer {
    fn score(
        &mut self,
        features: &[f32],
        weights: &[f32],
        mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.rt.placement_score(features, weights, mask)
    }
    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// One logged placement decision (the paper writes these to Elasticsearch
/// "for further analysis by operators").
#[derive(Debug, Clone)]
pub struct Decision {
    pub at: EpochMs,
    pub dataset: DidKey,
    pub chosen_rse: String,
    pub prob: f32,
    pub rule_id: u64,
    pub candidates: usize,
}

/// The C3PO daemon.
pub struct C3po {
    pub ctx: Ctx,
    pub scorer: Box<dyn Scorer>,
    pub weights: [f32; N_FEATURES],
    /// Popularity threshold (window accesses) triggering placement.
    pub threshold: u64,
    /// Max total replicas of a dataset before we stop adding more.
    pub max_replicas: usize,
    /// Per-dataset cool-down ("checks if there has already been a replica
    /// created in the recent past").
    pub cooldown_ms: i64,
    /// Lifetime of dynamic replicas (cache semantics).
    pub lifetime_ms: i64,
    pub per_tick: usize,
    last_placed: BTreeMap<DidKey, EpochMs>,
    recent_per_rse: BTreeMap<String, (EpochMs, u32)>,
    pub decisions: Vec<Decision>,
}

impl C3po {
    pub fn new(ctx: Ctx, scorer: Box<dyn Scorer>) -> Self {
        let cfg = &ctx.catalog.cfg;
        C3po {
            threshold: cfg.get_i64("c3po", "threshold", 5) as u64,
            max_replicas: cfg.get_i64("c3po", "max_replicas", 5) as usize,
            cooldown_ms: cfg.get_duration_ms("c3po", "cooldown", 3 * DAY_MS),
            lifetime_ms: cfg.get_duration_ms("c3po", "lifetime", 14 * DAY_MS),
            per_tick: cfg.get_i64("c3po", "per_tick", 8) as usize,
            ctx,
            scorer,
            weights: DEFAULT_WEIGHTS,
            last_placed: BTreeMap::new(),
            recent_per_rse: BTreeMap::new(),
            decisions: Vec::new(),
        }
    }

    /// Candidate datasets: popular in the current window, cooled down.
    fn hot_datasets(&self, now: EpochMs) -> Vec<DidKey> {
        let cat = &self.ctx.catalog;
        let mut hot: Vec<(u64, DidKey)> = Vec::new();
        cat.popularity.for_each(|p| {
            if p.window_accesses >= self.threshold {
                if let Some(t) = self.last_placed.get(&p.did) {
                    if now - *t < self.cooldown_ms {
                        return;
                    }
                }
                if let Ok(d) = cat.get_did(&p.did) {
                    if d.did_type == DidType::Dataset {
                        hot.push((p.window_accesses, p.did.clone()));
                    }
                }
            }
        });
        hot.sort_by(|a, b| b.0.cmp(&a.0));
        hot.into_iter().take(self.per_tick).map(|(_, k)| k).collect()
    }

    /// RSEs currently holding (available) data of the dataset, plus the
    /// subset holding a *complete* copy (every file) — the unit the paper
    /// counts as "how many replicas already exist".
    fn holding_rses(&self, dataset: &DidKey) -> (Vec<String>, Vec<String>) {
        let cat = &self.ctx.catalog;
        let files = cat.resolve_files(dataset);
        let mut per_rse: BTreeMap<String, usize> = BTreeMap::new();
        for f in &files {
            for r in cat.available_replicas(&f.key) {
                *per_rse.entry(r.rse).or_insert(0) += 1;
            }
        }
        let any: Vec<String> = per_rse.keys().cloned().collect();
        let full: Vec<String> = per_rse
            .iter()
            .filter(|(_, n)| **n == files.len() && !files.is_empty())
            .map(|(r, _)| r.clone())
            .collect();
        (any, full)
    }

    /// Build the candidate feature matrix for a dataset. Returns
    /// (rse names, features row-major, mask).
    pub fn build_features(
        &self,
        dataset: &DidKey,
        now: EpochMs,
    ) -> (Vec<String>, Vec<f32>, Vec<f32>) {
        let cat = &self.ctx.catalog;
        let (holding, full_holders) = self.holding_rses(dataset);
        let popularity = cat
            .popularity
            .get(dataset)
            .map(|p| p.window_accesses)
            .unwrap_or(0) as f32;
        let mut names = Vec::new();
        let mut features = Vec::new();
        let mut mask = Vec::new();
        // Pending requests per destination RSE (queue-pressure signal).
        // WAITING counts too: with the throttler enabled a flooded
        // destination parks its backlog in admission, and placement must
        // still see that pressure.
        let mut queued: BTreeMap<String, u32> = BTreeMap::new();
        for state in [RequestState::Waiting, RequestState::Queued] {
            for id in cat.requests_by_state.get(&state) {
                if let Some(r) = cat.requests.get(&id) {
                    *queued.entry(r.dst_rse).or_insert(0) += 1;
                }
            }
        }
        let ds_bytes = cat.did_bytes(dataset);
        for rse in cat.list_rses() {
            if rse.is_tape || !rse.availability_write || full_holders.contains(&rse.name) {
                continue;
            }
            // Free-space feature: log-scaled absolute headroom (a big empty
            // site beats a small empty site); candidates that cannot hold
            // the dataset with 2x headroom are masked out entirely.
            let free_bytes = match self.ctx.fleet.get(&rse.name) {
                Some(sys) => sys.free(),
                None => 100 * GB, // unknown backend: assume roomy
            };
            if free_bytes < ds_bytes.saturating_mul(2) {
                continue;
            }
            let free_feat = (free_bytes as f32).max(1.0).log10() / 12.0;
            // Best observed bandwidth from any holding site into this RSE.
            let mut best_bw = 0f32;
            let mut best_dist = 6u32;
            for src in &holding {
                let src_site = cat.get_rse(src).map(|r| r.site().to_string()).unwrap_or_default();
                if let Some(bps) = self.ctx.net.observed_bps(&src_site, rse.site()) {
                    best_bw = best_bw.max(bps as f32);
                }
                if let Some(d) = cat.distance(src, &rse.name) {
                    best_dist = best_dist.min(d);
                }
            }
            let recent = self
                .recent_per_rse
                .get(&rse.name)
                .filter(|(t, _)| now - *t < DAY_MS)
                .map(|(_, n)| *n)
                .unwrap_or(0) as f32;
            let load = self
                .ctx
                .net
                .active_on(
                    holding.first().map(|s| s.as_str()).unwrap_or(""),
                    rse.site(),
                ) as f32;
            names.push(rse.name.clone());
            features.extend_from_slice(&[
                free_feat,                          // f0: log free space
                (best_bw / GB as f32).min(4.0),     // f1: observed bw (GB/s)
                (queued.get(&rse.name).copied().unwrap_or(0) as f32 / 100.0).min(4.0), // f2
                (recent / 10.0).min(4.0),           // f3: recent placements
                (popularity / 20.0).min(4.0),       // f4: dataset popularity
                (6.0 - best_dist as f32) / 5.0,     // f5: closeness
                (load / 20.0).min(4.0),             // f6: link load
                1.0,                                // f7: bias
            ]);
            mask.push(1.0);
        }
        (names, features, mask)
    }

    /// Run placement for one dataset; returns the created rule id.
    pub fn place(&mut self, dataset: &DidKey, now: EpochMs) -> Result<Option<u64>> {
        let cat = self.ctx.catalog.clone();
        let (holding, full_holders) = self.holding_rses(dataset);
        // The cap counts complete dataset replicas (the paper's "how many
        // replicas already exist below a configurable threshold").
        if holding.is_empty() || full_holders.len() >= self.max_replicas {
            return Ok(None);
        }
        let (names, features, mask) = self.build_features(dataset, now);
        if names.is_empty() {
            return Ok(None);
        }
        let weights = self.weights;
        let (_scores, probs) = self.scorer.score(&features, &weights, &mask)?;
        let best = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, p)| (i, *p));
        let Some((idx, prob)) = best else { return Ok(None) };
        let rse = names[idx].clone();
        let rule_id = cat.add_rule(
            RuleSpec::new("root", dataset.clone(), &rse, 1)
                .with_lifetime(self.lifetime_ms)
                .with_activity(CACHE_ACTIVITY),
        )?;
        self.last_placed.insert(dataset.clone(), now);
        let entry = self.recent_per_rse.entry(rse.clone()).or_insert((now, 0));
        if now - entry.0 > DAY_MS {
            *entry = (now, 1);
        } else {
            entry.1 += 1;
        }
        self.decisions.push(Decision {
            at: now,
            dataset: dataset.clone(),
            chosen_rse: rse.clone(),
            prob,
            rule_id,
            candidates: names.len(),
        });
        cat.notify(
            "c3po-decision",
            Json::obj()
                .with("scope", dataset.scope.as_str())
                .with("name", dataset.name.as_str())
                .with("rse", rse.as_str())
                .with("prob", prob as f64)
                .with("rule_id", rule_id),
        );
        cat.metrics.incr("c3po.placements", 1);
        Ok(Some(rule_id))
    }

    /// Start the per-dataset cool-down clock without placing (used by the
    /// fleet daemon when a placement attempt yields no candidates, so the
    /// dataset is not rescanned every tick).
    pub fn mark_cooldown(&mut self, did: &DidKey, now: EpochMs) {
        self.last_placed.insert(did.clone(), now);
    }

    /// Whether the dataset is still inside its placement cool-down.
    pub fn in_cooldown(&self, did: &DidKey, now: EpochMs) -> bool {
        self.last_placed
            .get(did)
            .is_some_and(|t| now - *t < self.cooldown_ms)
    }
}

impl Daemon for C3po {
    fn name(&self) -> &'static str {
        "c3po"
    }

    fn interval_ms(&self) -> i64 {
        60_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let hot = self.hot_datasets(now);
        let mut placed = 0;
        for ds in hot {
            match self.place(&ds, now) {
                Ok(Some(_)) => placed += 1,
                Ok(None) => {
                    // cap reached or no candidates: cool down anyway so we
                    // do not rescan it every tick
                    self.last_placed.insert(ds, now);
                }
                Err(e) => crate::log_warn!("c3po: placement failed for {ds}: {e}"),
            }
        }
        placed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rse::Rse;
    use crate::daemons::conveyor::tests::{rig, seed_file};
    use crate::storagesim::{StorageKind, StorageSystem};

    fn hot_rig() -> (Ctx, DidKey) {
        let (ctx, cat) = rig();
        let now = cat.now();
        // extra candidate RSEs with differing free space
        for (name, cap) in [("BIG-DISK", 1_000_000_000u64), ("SMALL-DISK", 1_000u64)] {
            cat.add_rse(Rse::new(name, now).with_attr("site", name)).unwrap();
            ctx.fleet.add(StorageSystem::new(name, StorageKind::Disk, cap));
        }
        cat.add_dataset("data18", "hot.ds", "root").unwrap();
        let ds = DidKey::new("data18", "hot.ds");
        let f = seed_file(&ctx, "hot.f1", 500);
        cat.attach(&ds, &f).unwrap();
        // make it popular
        for _ in 0..5 {
            cat.touch_replica("SRC-DISK", &f);
        }
        (ctx, ds)
    }

    #[test]
    fn popular_dataset_gets_placed_on_spacious_rse() {
        let (ctx, ds) = hot_rig();
        let cat = ctx.catalog.clone();
        let mut c3po = C3po::new(ctx, Box::new(RefScorer));
        let placed = c3po.tick(cat.now());
        assert_eq!(placed, 1);
        let d = &c3po.decisions[0];
        assert_eq!(d.dataset, ds);
        // free-space weight dominates → BIG-DISK (SMALL-DISK can't even
        // hold the file, free_frac low)
        assert_ne!(d.chosen_rse, "SMALL-DISK");
        let rule = cat.get_rule(d.rule_id).unwrap();
        assert_eq!(rule.activity, "Dynamic Placement");
        assert!(rule.expires_at.is_some(), "dynamic replicas have lifetimes");
    }

    #[test]
    fn cooldown_prevents_thrash() {
        let (ctx, _ds) = hot_rig();
        let cat = ctx.catalog.clone();
        let mut c3po = C3po::new(ctx, Box::new(RefScorer));
        assert_eq!(c3po.tick(cat.now()), 1);
        assert_eq!(c3po.tick(cat.now()), 0, "cooldown holds");
    }

    #[test]
    fn unpopular_dataset_ignored() {
        let (ctx, cat) = rig();
        cat.add_dataset("data18", "cold.ds", "root").unwrap();
        let ds = DidKey::new("data18", "cold.ds");
        let f = seed_file(&ctx, "cold.f1", 100);
        cat.attach(&ds, &f).unwrap();
        cat.touch_replica("SRC-DISK", &f); // 1 access < threshold 3
        let mut c3po = C3po::new(ctx, Box::new(RefScorer));
        assert_eq!(c3po.tick(cat.now()), 0);
    }

    #[test]
    fn max_replica_cap_respected() {
        let (ctx, ds) = hot_rig();
        let cat = ctx.catalog.clone();
        let mut c3po = C3po::new(ctx.clone(), Box::new(RefScorer));
        c3po.max_replicas = 1; // already holding on SRC-DISK
        assert_eq!(c3po.tick(cat.now()), 0);
        let _ = ds;
    }

    #[test]
    fn pjrt_and_ref_scorers_agree_on_decision() {
        if !crate::runtime::artifacts_available() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let (ctx, ds) = hot_rig();
        let cat = ctx.catalog.clone();
        let now = cat.now();
        let probe = C3po::new(ctx.clone(), Box::new(RefScorer));
        let (names, features, mask) = probe.build_features(&ds, now);
        let mut ref_s = RefScorer;
        let mut pjrt_s = PjrtScorer::load_default().unwrap();
        let (_, p_ref) = ref_s.score(&features, &DEFAULT_WEIGHTS, &mask).unwrap();
        let (_, p_pjrt) = pjrt_s.score(&features, &DEFAULT_WEIGHTS, &mask).unwrap();
        let argmax = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        assert_eq!(argmax(&p_ref), argmax(&p_pjrt), "{names:?}");
    }
}
