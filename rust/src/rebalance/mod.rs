//! BB8 — automated data rebalancing (paper §6.2), three modes:
//! * **background**: equalize the primary/secondary byte ratio across
//!   participating RSEs (attribute `bb8=true`), preferring old, unpopular,
//!   long-lifetime rules;
//! * **decommission**: drain an RSE entirely, honouring each rule's
//!   original RSE expression;
//! * **manual**: move a requested volume away from an RSE.
//!
//! Moves are expressed as new linked rules ("the service links the
//! original replication rule with the newly created one and only allows
//! the removal of the original rule once the data has been fully
//! replicated"); a per-day volume cap protects the network.

use std::collections::BTreeMap;

use crate::common::clock::{DAY_MS, EpochMs};
use crate::common::error::{Result, RucioError};
use crate::core::rules_api::RuleSpec;
use crate::core::types::{LockState, Rule, RuleState};

use crate::daemons::{Ctx, Daemon};

/// An in-flight move: delete `old_rule` once `new_rule` is OK.
#[derive(Debug, Clone)]
pub struct Move {
    pub old_rule: u64,
    pub new_rule: u64,
    pub bytes: u64,
    pub started_at: EpochMs,
}

pub struct Bb8 {
    pub ctx: Ctx,
    /// Max bytes moved per day (config `bb8.max_daily_bytes`).
    pub max_daily_bytes: u64,
    /// Give up on a move whose child rule has not converged after this
    /// long (config `bb8.abandon_timeout`): the child is deleted, the
    /// original rule unpinned, and its bytes credited back to the daily
    /// budget.
    pub abandon_timeout_ms: i64,
    day_start: EpochMs,
    moved_today: u64,
    pub in_flight: Vec<Move>,
    pub completed_moves: u64,
}

impl Bb8 {
    pub fn new(ctx: Ctx) -> Self {
        let max_daily =
            ctx.catalog.cfg.get_bytes("bb8", "max_daily_bytes", 50 * crate::common::units::TB);
        let abandon_timeout_ms =
            ctx.catalog.cfg.get_duration_ms("bb8", "abandon_timeout", 2 * DAY_MS);
        Bb8 {
            ctx,
            max_daily_bytes: max_daily,
            abandon_timeout_ms,
            day_start: 0,
            moved_today: 0,
            in_flight: Vec::new(),
            completed_moves: 0,
        }
    }

    /// Rules wholly resident (all locks OK) on `rse`, rebalancing-eligible:
    /// not already linked, expression not pinning that single RSE.
    fn movable_rules(&self, rse: &str) -> Vec<Rule> {
        let cat = &self.ctx.catalog;
        let mut out = Vec::new();
        cat.rules.for_each(|r| {
            if r.state != RuleState::Ok || r.child_rule.is_some() {
                return;
            }
            // the expression must allow other destinations
            if r.rse_expression == rse {
                return;
            }
            let locks = cat.locks_by_rule.get(&r.id);
            if locks.is_empty() {
                return;
            }
            let all_here = locks
                .iter()
                .filter_map(|k| cat.locks.get(k))
                .all(|l| l.rse == rse && l.state == LockState::Ok);
            if all_here {
                out.push(r.clone());
            }
        });
        // Prefer old, unpopular data (paper: "older, unpopular data, with
        // a long lifetime is preferred").
        out.sort_by_key(|r| {
            let pop = self
                .ctx
                .catalog
                .popularity
                .get(&r.did)
                .map(|p| p.window_accesses)
                .unwrap_or(0);
            (pop, r.created_at)
        });
        out
    }

    fn rule_bytes(&self, rule_id: u64) -> u64 {
        self.ctx
            .catalog
            .locks_by_rule
            .get(&rule_id)
            .iter()
            .filter_map(|k| self.ctx.catalog.locks.get(k))
            .map(|l| l.bytes)
            .sum()
    }

    /// Move one rule away from `src_rse`: create the linked child rule on
    /// `(<original expression>)\SRC`, following the original policy.
    pub fn move_rule(&mut self, rule: &Rule, src_rse: &str, now: EpochMs) -> Result<u64> {
        let cat = &self.ctx.catalog;
        let dest_expr = format!("({})\\{}", rule.rse_expression, src_rse);
        // Destination must be non-empty.
        let resolved = cat.resolve_rse_expression(&dest_expr).map_err(|_| {
            RucioError::InvalidValue(format!(
                "rule {} has no alternative destination ({dest_expr})",
                rule.id
            ))
        })?;
        let _ = resolved;
        let mut spec = RuleSpec::new(&rule.account, rule.did.clone(), &dest_expr, rule.copies)
            .with_activity("Data Rebalancing");
        if let Some(exp) = rule.expires_at {
            spec = spec.with_lifetime((exp - now).max(60_000));
        }
        let new_rule = cat.add_rule(spec)?;
        cat.rules.update(&rule.id, now, |r| r.child_rule = Some(new_rule));
        let bytes = self.rule_bytes(rule.id);
        self.in_flight.push(Move { old_rule: rule.id, new_rule, bytes, started_at: now });
        self.moved_today += bytes;
        cat.metrics.incr("bb8.moves_started", 1);
        cat.metrics.incr("bb8.bytes_scheduled", bytes);
        Ok(new_rule)
    }

    /// Finish moves whose child rule is OK: delete the original rule
    /// (freeing the source replicas for the reaper). Moves whose child
    /// has not converged within `bb8.abandon_timeout` are abandoned —
    /// the failed child is deleted, the original rule unpinned, and the
    /// scheduled bytes credited back to today's budget, so a STUCK child
    /// can neither pin its source forever nor eat the daily cap. A child
    /// that vanished outright (expired mid-move) is counted as lost and
    /// the source rule left eligible for the next pass.
    pub fn finalize_moves(&mut self, now: EpochMs) -> usize {
        let cat = self.ctx.catalog.clone();
        let mut done = 0;
        let mut remaining = Vec::new();
        for mv in self.in_flight.drain(..) {
            match cat.rules.get(&mv.new_rule) {
                Some(child) if child.state == RuleState::Ok => {
                    let _ = cat.delete_rule(mv.old_rule);
                    done += 1;
                    cat.metrics.incr("bb8.moves_completed", 1);
                }
                Some(_) if now - mv.started_at > self.abandon_timeout_ms => {
                    let _ = cat.delete_rule(mv.new_rule);
                    cat.rules.update(&mv.old_rule, now, |r| r.child_rule = None);
                    self.moved_today = self.moved_today.saturating_sub(mv.bytes);
                    cat.metrics.incr("bb8.moves_abandoned", 1);
                }
                Some(_) => remaining.push(mv),
                None => {
                    // child vanished (expired?) — drop the link; the rule
                    // becomes movable again on the next pass
                    cat.rules.update(&mv.old_rule, now, |r| r.child_rule = None);
                    self.moved_today = self.moved_today.saturating_sub(mv.bytes);
                    cat.metrics.incr("bb8.moves_lost", 1);
                }
            }
        }
        self.in_flight = remaining;
        self.completed_moves += done as u64;
        done
    }

    /// Background mode: equalize locked-bytes share across `bb8=true`
    /// RSEs — move rules off RSEs above the average until the daily cap.
    pub fn background_pass(&mut self, now: EpochMs) -> usize {
        let cat = self.ctx.catalog.clone();
        // locked (primary) bytes per participating RSE
        let mut primary: BTreeMap<String, u64> = BTreeMap::new();
        let participants: Vec<String> = cat
            .list_rses()
            .into_iter()
            .filter(|r| r.attr("bb8") == Some("true"))
            .map(|r| r.name)
            .collect();
        if participants.len() < 2 {
            return 0;
        }
        for rse in &participants {
            primary.insert(rse.clone(), 0);
        }
        cat.locks.for_each(|l| {
            if let Some(v) = primary.get_mut(&l.rse) {
                *v += l.bytes;
            }
        });
        let avg: u64 = primary.values().sum::<u64>() / participants.len() as u64;
        let mut started = 0;
        for (rse, bytes) in primary.iter() {
            if *bytes <= avg {
                continue;
            }
            let mut excess = *bytes - avg;
            for rule in self.movable_rules(rse) {
                if excess == 0 || self.moved_today >= self.max_daily_bytes {
                    break;
                }
                let rb = self.rule_bytes(rule.id);
                if self.move_rule(&rule, rse, now).is_ok() {
                    excess = excess.saturating_sub(rb);
                    started += 1;
                }
            }
        }
        started
    }

    /// Schedule every currently-movable rule off `rse`. One shot of the
    /// decommission drain; the fleet daemon re-runs it on later ticks to
    /// catch rules that became movable afterwards (replication finished,
    /// a move was abandoned or lost).
    pub fn drain_pass(&mut self, rse: &str, now: EpochMs) -> usize {
        let mut moved = 0;
        for rule in self.movable_rules(rse) {
            if self.move_rule(&rule, rse, now).is_ok() {
                moved += 1;
            }
        }
        moved
    }

    /// Decommission mode: drain everything off `rse` (paper: "selects all
    /// data resident on the RSE and moves it to a different RSE, following
    /// the original RSE expression policies"). Also disables writes.
    pub fn decommission(&mut self, rse: &str, now: EpochMs) -> Result<usize> {
        let cat = self.ctx.catalog.clone();
        cat.set_rse_availability(rse, true, false, true)?;
        let moved = self.drain_pass(rse, now);
        cat.metrics.incr("bb8.decommissions", 1);
        Ok(moved)
    }

    /// Manual mode: move ~`bytes` off `rse`.
    pub fn manual(&mut self, rse: &str, bytes: u64, now: EpochMs) -> Result<usize> {
        let mut remaining = bytes as i64;
        let mut moved = 0;
        for rule in self.movable_rules(rse) {
            if remaining <= 0 {
                break;
            }
            let rb = self.rule_bytes(rule.id) as i64;
            if self.move_rule(&rule, rse, now).is_ok() {
                remaining -= rb;
                moved += 1;
            }
        }
        Ok(moved)
    }
}

impl Daemon for Bb8 {
    fn name(&self) -> &'static str {
        "bb8"
    }

    fn interval_ms(&self) -> i64 {
        300_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        if now - self.day_start > DAY_MS {
            self.day_start = now;
            self.moved_today = 0;
        }
        let finalized = self.finalize_moves(now);
        let started = if self.moved_today < self.max_daily_bytes {
            self.background_pass(now)
        } else {
            0
        };
        finalized + started
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::RequestState;
    use crate::daemons::conveyor::tests::{rig, seed_file};

    /// Build: SRC-DISK over-full with 3 rules, DST-A/DST-B empty, all bb8.
    fn unbalanced() -> (Ctx, Bb8) {
        let (ctx, cat) = rig();
        for rse in ["SRC-DISK", "DST-A", "DST-B"] {
            cat.set_rse_attribute(rse, "bb8", "true").unwrap();
        }
        for i in 0..3 {
            let f = seed_file(&ctx, &format!("b{i}"), 1000);
            cat.add_rule(
                RuleSpec::new("root", f, "SRC-DISK|DST-A|DST-B", 1), // already satisfied at SRC
            )
            .unwrap();
        }
        let bb8 = Bb8::new(ctx.clone());
        (ctx, bb8)
    }

    fn drive_transfers(ctx: &Ctx) {
        // complete all queued requests instantly (unit-test shortcut)
        let cat = &ctx.catalog;
        loop {
            let queued = cat.requests_by_state.get(&RequestState::Queued);
            if queued.is_empty() {
                break;
            }
            for id in queued {
                cat.on_transfer_done(id).unwrap();
            }
        }
    }

    #[test]
    fn background_equalizes_and_links_rules() {
        let (ctx, mut bb8) = unbalanced();
        let cat = ctx.catalog.clone();
        let started = bb8.background_pass(cat.now());
        assert!(started >= 1, "moves started");
        // old rule is linked to the child
        let mv = bb8.in_flight[0].clone();
        let old = cat.get_rule(mv.old_rule).unwrap();
        assert_eq!(old.child_rule, Some(mv.new_rule));
        // original rule NOT deleted while the child replicates
        assert_eq!(bb8.finalize_moves(cat.now()), 0);
        assert!(cat.get_rule(mv.old_rule).is_ok());
        // child's destination excludes the source
        let child = cat.get_rule(mv.new_rule).unwrap();
        assert!(child.rse_expression.contains("\\SRC-DISK"));
        // complete transfers → finalize deletes the original
        drive_transfers(&ctx);
        let done = bb8.finalize_moves(cat.now());
        assert!(done >= 1);
        assert!(cat.get_rule(mv.old_rule).is_err(), "original removed after move");
    }

    #[test]
    fn decommission_drains_and_disables_writes() {
        let (ctx, mut bb8) = unbalanced();
        let cat = ctx.catalog.clone();
        let moved = bb8.decommission("SRC-DISK", cat.now()).unwrap();
        assert_eq!(moved, 3, "all resident rules scheduled away");
        assert!(!cat.get_rse("SRC-DISK").unwrap().availability_write);
        drive_transfers(&ctx);
        bb8.finalize_moves(cat.now());
        // no rule keeps locks on the drained RSE
        let mut locks_on_src = 0;
        cat.locks.for_each(|l| {
            if l.rse == "SRC-DISK" {
                locks_on_src += 1;
            }
        });
        assert_eq!(locks_on_src, 0);
    }

    #[test]
    fn manual_moves_requested_volume() {
        let (ctx, mut bb8) = unbalanced();
        let cat = ctx.catalog.clone();
        let moved = bb8.manual("SRC-DISK", 1500, cat.now()).unwrap();
        assert_eq!(moved, 2, "two 1000-byte rules cover 1500 bytes");
    }

    #[test]
    fn stuck_child_abandoned_after_timeout() {
        let (ctx, mut bb8) = unbalanced();
        let cat = ctx.catalog.clone();
        bb8.max_daily_bytes = 1000; // exactly one move fits the budget
        assert_eq!(bb8.background_pass(cat.now()), 1);
        let mv = bb8.in_flight[0].clone();
        let budget_before = bb8.moved_today;
        // force the child rule STUCK: exhaust every transfer attempt
        for req in cat.requests.scan(|r| r.rule_id == mv.new_rule) {
            for _ in 0..3 {
                cat.on_transfer_failed(req.id, "dest refused").unwrap();
            }
        }
        assert_eq!(cat.get_rule(mv.new_rule).unwrap().state, RuleState::Stuck);
        // within the abandon window the move stays pending
        assert_eq!(bb8.finalize_moves(cat.now()), 0);
        assert_eq!(bb8.in_flight.len(), 1, "stuck move still pending inside the window");
        // past the window: child deleted, source unpinned, budget refunded
        let later = cat.now() + bb8.abandon_timeout_ms + 1;
        assert_eq!(bb8.finalize_moves(later), 0);
        assert!(
            !bb8.in_flight.iter().any(|m| m.old_rule == mv.old_rule),
            "abandoned move leaves in_flight"
        );
        assert!(cat.get_rule(mv.new_rule).is_err(), "failed child rule removed");
        assert_eq!(cat.get_rule(mv.old_rule).unwrap().child_rule, None);
        assert!(bb8.moved_today < budget_before, "scheduled bytes credited back");
        assert_eq!(cat.metrics.counter("bb8.moves_abandoned"), 1);
        // the source rule is movable again on the next pass
        assert!(bb8.background_pass(cat.now()) >= 1, "rule re-eligible after abandon");
    }

    #[test]
    fn vanished_child_counted_lost_and_rule_retried() {
        let (ctx, mut bb8) = unbalanced();
        let cat = ctx.catalog.clone();
        bb8.max_daily_bytes = 1000; // exactly one move fits the budget
        assert_eq!(bb8.background_pass(cat.now()), 1);
        let mv = bb8.in_flight[0].clone();
        let budget_before = bb8.moved_today;
        // the child rule expires mid-move (judge-cleaner sweep)
        cat.rules.update(&mv.new_rule, cat.now(), |r| r.expires_at = Some(cat.now() - 1));
        assert_eq!(cat.process_expired_rules(10), 1);
        assert!(cat.get_rule(mv.new_rule).is_err());
        assert_eq!(bb8.finalize_moves(cat.now()), 0);
        assert_eq!(cat.metrics.counter("bb8.moves_lost"), 1);
        assert!(
            !bb8.in_flight.iter().any(|m| m.old_rule == mv.old_rule),
            "lost move is dropped from in_flight"
        );
        assert_eq!(cat.get_rule(mv.old_rule).unwrap().child_rule, None);
        assert!(bb8.moved_today < budget_before, "lost bytes credited back");
        // the stranded rule is picked up again by the next pass
        let retried = bb8.background_pass(cat.now());
        assert!(retried >= 1, "source rule eligible for retry after loss");
        assert!(bb8.in_flight.iter().any(|m| m.old_rule == mv.old_rule));
    }

    #[test]
    fn daily_cap_limits_moves() {
        let (ctx, mut bb8) = unbalanced();
        bb8.max_daily_bytes = 1000; // one rule's worth
        let started = bb8.background_pass(ctx.catalog.now());
        assert_eq!(started, 1);
    }

    #[test]
    fn single_rse_expression_rules_not_movable() {
        let (ctx, cat) = rig();
        cat.set_rse_attribute("SRC-DISK", "bb8", "true").unwrap();
        cat.set_rse_attribute("DST-A", "bb8", "true").unwrap();
        let f = seed_file(&ctx, "pin", 1000);
        cat.add_rule(RuleSpec::new("root", f, "SRC-DISK", 1)).unwrap();
        let mut bb8 = Bb8::new(ctx.clone());
        // pinned rule's expression has no alternative → not movable
        assert_eq!(bb8.background_pass(cat.now()), 0);
    }
}
