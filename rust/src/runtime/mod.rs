//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path —
//! Python never runs at request time.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are lowered with
//! `return_tuple=True`, so every execution returns one tuple literal that
//! is unpacked here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::common::error::{Result, RucioError};
use crate::jsonx::Json;

fn rt_err<E: std::fmt::Display>(what: &'static str) -> impl FnOnce(E) -> RucioError {
    move |e| RucioError::RuntimeError(format!("{what}: {e}"))
}

/// Artifact manifest (artifacts/manifest.json).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub placement_n: usize,
    pub n_features: usize,
    pub t3c_batch: usize,
    pub t3c_hidden: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;
        Ok(Manifest {
            placement_n: j.req_u64("placement_n")? as usize,
            n_features: j.req_u64("n_features")? as usize,
            t3c_batch: j.req_u64("t3c_batch")? as usize,
            t3c_hidden: j.req_u64("t3c_hidden")? as usize,
        })
    }
}

/// T³C MLP parameters (mirrors `model.t3c_init` layout).
#[derive(Debug, Clone)]
pub struct T3cParams {
    pub w1: Vec<f32>, // (d, h) row-major
    pub b1: Vec<f32>, // (h)
    pub w2: Vec<f32>, // (h, 1)
    pub b2: Vec<f32>, // (1)
    pub d: usize,
    pub h: usize,
}

impl T3cParams {
    /// Load the Python-initialized parameters (artifacts/t3c_params.bin).
    pub fn load(dir: &Path, d: usize, h: usize) -> Result<T3cParams> {
        let bytes = std::fs::read(dir.join("t3c_params.bin"))?;
        let total = d * h + h + h + 1;
        if bytes.len() != total * 4 {
            return Err(RucioError::RuntimeError(format!(
                "t3c_params.bin: expected {} floats, got {} bytes",
                total,
                bytes.len()
            )));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let (w1, rest) = floats.split_at(d * h);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(h);
        Ok(T3cParams {
            w1: w1.to_vec(),
            b1: b1.to_vec(),
            w2: w2.to_vec(),
            b2: b2.to_vec(),
            d,
            h,
        })
    }
}

/// The PJRT runtime holding compiled executables.
///
/// NOT `Sync` (PJRT handles are raw pointers); each daemon owns its own
/// `Runtime` instance — compilation is cheap at these shapes.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    execs: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    pub dir: PathBuf,
}

// SAFETY: `Runtime` is moved wholesale into a single daemon thread and
// never shared (`!Sync` stays). The inner `Rc` is never cloned across
// threads and PJRT CPU handles are not thread-affine, so transferring
// ownership between threads is sound.
unsafe impl Send for Runtime {}

/// Default artifact directory (repo-relative, overridable via env).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("RUCIO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// True when `make artifacts` has been run.
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}

impl Runtime {
    /// Load + compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(rt_err("PjRtClient::cpu"))?;
        let mut execs = BTreeMap::new();
        for name in ["placement_score", "t3c_predict", "t3c_train_step"] {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| RucioError::RuntimeError("non-utf8 path".into()))?,
            )
            .map_err(rt_err("parse hlo"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(rt_err("compile"))?;
            execs.insert(name.to_string(), exe);
        }
        Ok(Runtime { client, execs, manifest, dir: dir.to_path_buf() })
    }

    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&default_artifact_dir())
    }

    /// Execute an artifact on f32 tensors: `(data, shape)` per input.
    /// Returns the flattened f32 data of every tuple output element.
    pub fn run_f32(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| RucioError::RuntimeError(format!("unknown artifact {name}")))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(rt_err("reshape"))?;
            literals.push(lit);
        }
        let mut result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(rt_err("execute"))?[0][0]
            .to_literal_sync()
            .map_err(rt_err("fetch"))?;
        let tuple = result.decompose_tuple().map_err(rt_err("untuple"))?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>().map_err(rt_err("to_vec"))?);
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // typed wrappers
    // ------------------------------------------------------------------

    /// C3PO placement scoring: features [n×d] (row-major), weights [d],
    /// mask [n]; pads to the artifact shape. Returns (scores, probs),
    /// truncated back to the caller's n.
    pub fn placement_score(
        &self,
        features: &[f32],
        weights: &[f32],
        mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (n_art, d) = (self.manifest.placement_n, self.manifest.n_features);
        let n = mask.len();
        if n > n_art {
            return Err(RucioError::RuntimeError(format!(
                "too many candidates: {n} > artifact capacity {n_art}"
            )));
        }
        if features.len() != n * d || weights.len() != d {
            return Err(RucioError::RuntimeError("feature shape mismatch".into()));
        }
        let mut f_pad = vec![0f32; n_art * d];
        f_pad[..n * d].copy_from_slice(features);
        let mut m_pad = vec![0f32; n_art];
        m_pad[..n].copy_from_slice(mask);
        let out = self.run_f32(
            "placement_score",
            &[(&f_pad, &[n_art, d]), (weights, &[d]), (&m_pad, &[n_art])],
        )?;
        let scores = out[0][..n].to_vec();
        let probs = out[1][..n].to_vec();
        Ok((scores, probs))
    }

    /// T³C forward: predicts log-durations for up to `t3c_batch` feature
    /// rows (padded internally).
    pub fn t3c_predict(&self, params: &T3cParams, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let (b, d, h) =
            (self.manifest.t3c_batch, self.manifest.n_features, self.manifest.t3c_hidden);
        if rows > b || x.len() != rows * d {
            return Err(RucioError::RuntimeError(format!(
                "t3c_predict: rows={rows} (cap {b}), xlen={}",
                x.len()
            )));
        }
        let mut x_pad = vec![0f32; b * d];
        x_pad[..rows * d].copy_from_slice(x);
        let out = self.run_f32(
            "t3c_predict",
            &[
                (&params.w1, &[d, h]),
                (&params.b1, &[h]),
                (&params.w2, &[h, 1]),
                (&params.b2, &[1]),
                (&x_pad, &[b, d]),
            ],
        )?;
        Ok(out[0][..rows].to_vec())
    }

    /// One online SGD step on a (padded) batch; returns (loss, params').
    pub fn t3c_train_step(
        &self,
        params: &T3cParams,
        x: &[f32],
        y: &[f32],
        rows: usize,
        lr: f32,
    ) -> Result<(f32, T3cParams)> {
        let (b, d, h) =
            (self.manifest.t3c_batch, self.manifest.n_features, self.manifest.t3c_hidden);
        if rows > b || rows == 0 {
            return Err(RucioError::RuntimeError(format!("bad batch rows={rows}")));
        }
        let mut x_pad = vec![0f32; b * d];
        x_pad[..rows * d].copy_from_slice(x);
        let mut y_pad = vec![0f32; b];
        y_pad[..rows].copy_from_slice(y);
        let mut m_pad = vec![0f32; b];
        m_pad[..rows].iter_mut().for_each(|v| *v = 1.0);
        let lr_arr = [lr];
        let out = self.run_f32(
            "t3c_train_step",
            &[
                (&params.w1, &[d, h]),
                (&params.b1, &[h]),
                (&params.w2, &[h, 1]),
                (&params.b2, &[1]),
                (&x_pad, &[b, d]),
                (&y_pad, &[b]),
                (&m_pad, &[b]),
                (&lr_arr, &[]),
            ],
        )?;
        let loss = out[0][0];
        let new = T3cParams {
            w1: out[1].clone(),
            b1: out[2].clone(),
            w2: out[3].clone(),
            b2: out[4].clone(),
            d,
            h,
        };
        Ok((loss, new))
    }
}

/// Pure-Rust reference scorer — mirror of `kernels/ref.py`. Used as the
/// fallback when artifacts are not built, and as the ablation baseline
/// (`benches/abl_scorer.rs`).
pub fn ref_placement_score(
    features: &[f32],
    weights: &[f32],
    mask: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let d = weights.len();
    let n = mask.len();
    let mut scores = vec![0f32; n];
    for i in 0..n {
        let row = &features[i * d..(i + 1) * d];
        let s: f32 = row.iter().zip(weights).map(|(a, b)| a * b).sum();
        scores[i] = if mask[i] > 0.5 { s } else { -1e30 };
    }
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs = vec![0f32; n];
    let mut z = 0f32;
    for i in 0..n {
        if mask[i] > 0.5 {
            probs[i] = (scores[i] - m).exp();
            z += probs[i];
        }
    }
    if z > 0.0 {
        probs.iter_mut().for_each(|p| *p /= z);
    }
    (scores, probs)
}

/// Pure-Rust T³C forward (mirror of `ref.mlp_ref`) — fallback predictor.
pub fn ref_t3c_predict(params: &T3cParams, x: &[f32], rows: usize) -> Vec<f32> {
    let (d, h) = (params.d, params.h);
    let mut out = vec![0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut acc = 0f32;
        for j in 0..h {
            let mut hj = params.b1[j];
            for i in 0..d {
                hj += xr[i] * params.w1[i * h + j];
            }
            if hj > 0.0 {
                acc += hj * params.w2[j];
            }
        }
        out[r] = acc + params.b2[0];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skip() -> bool {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return true;
        }
        false
    }

    #[test]
    fn ref_scorer_masks_and_normalizes() {
        let d = 8;
        let features: Vec<f32> = (0..3 * d).map(|i| (i % 5) as f32).collect();
        let weights = vec![1.0; d];
        let mask = vec![1.0, 0.0, 1.0];
        let (scores, probs) = ref_placement_score(&features, &weights, &mask);
        assert!(scores[1] < -1e29);
        assert_eq!(probs[1], 0.0);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn pjrt_placement_matches_ref() {
        if skip() {
            return;
        }
        let rt = Runtime::load_default().unwrap();
        let d = rt.manifest.n_features;
        let n = 10;
        let features: Vec<f32> =
            (0..n * d).map(|i| ((i * 37 % 11) as f32 - 5.0) / 3.0).collect();
        let weights: Vec<f32> = (0..d).map(|i| (i as f32 - 3.5) / 2.0).collect();
        let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let (s_pjrt, p_pjrt) = rt.placement_score(&features, &weights, &mask).unwrap();
        let (s_ref, p_ref) = ref_placement_score(&features, &weights, &mask);
        for i in 0..n {
            if mask[i] > 0.5 {
                assert!((s_pjrt[i] - s_ref[i]).abs() < 1e-3, "score {i}");
            }
            assert!((p_pjrt[i] - p_ref[i]).abs() < 1e-4, "prob {i}");
        }
    }

    #[test]
    fn pjrt_t3c_predict_matches_ref() {
        if skip() {
            return;
        }
        let rt = Runtime::load_default().unwrap();
        let params =
            T3cParams::load(&rt.dir, rt.manifest.n_features, rt.manifest.t3c_hidden).unwrap();
        let rows = 5;
        let x: Vec<f32> = (0..rows * params.d)
            .map(|i| ((i * 17 % 13) as f32 - 6.0) / 4.0)
            .collect();
        let got = rt.t3c_predict(&params, &x, rows).unwrap();
        let want = ref_t3c_predict(&params, &x, rows);
        for i in 0..rows {
            assert!((got[i] - want[i]).abs() < 1e-3, "{i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn pjrt_training_reduces_loss() {
        if skip() {
            return;
        }
        let rt = Runtime::load_default().unwrap();
        let mut params =
            T3cParams::load(&rt.dir, rt.manifest.n_features, rt.manifest.t3c_hidden).unwrap();
        let d = params.d;
        let rows = rt.manifest.t3c_batch;
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        let mut seed = 12345u64;
        for step in 0..60 {
            let mut x = vec![0f32; rows * d];
            let mut y = vec![0f32; rows];
            for r in 0..rows {
                let mut s = 0f32;
                for i in 0..d {
                    seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                    let v = ((seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5;
                    x[r * d + i] = v;
                    s += v;
                }
                y[r] = s / 2.0;
            }
            let (loss, new_params) = rt.t3c_train_step(&params, &x, &y, rows, 0.05).unwrap();
            params = new_params;
            if step == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first * 0.5, "no learning via PJRT: {first} -> {last}");
    }
}
