//! The REST server (paper §3.2/§3.3): "the REST interface is the main
//! entry-point to interact with Rucio" — a passive component relaying
//! requests into the core. Every route (except `/auth/*` and `/ping`)
//! requires a valid `X-Rucio-Auth-Token` and passes the permission policy.
//!
//! List responses stream as NDJSON (the paper's streamed replies).
//!
//! Bulk + cursor surface (paper §3.6 bulk operations):
//! * `POST /replicas/bulk` — `{rse, replicas: [{scope, name, pfn?,
//!   state?}]}` registers the whole batch through one batched catalog
//!   commit; atomic (any bad entry fails the call with no partial state).
//! * `POST /rules/bulk` — `{rules: [<rule spec>, ...]}` creates many
//!   rules, each landing its locks/requests as batches; replies
//!   `{rule_ids: [...]}`. Atomic: a mid-batch failure rolls back the
//!   rules already created by the call.
//! * `GET /rules?cursor=&limit=` and `GET /replicas?cursor=&limit=` —
//!   cursor-paginated NDJSON over the full tables; when more pages
//!   remain the reply carries `x-rucio-next-cursor` (pass it back as
//!   `cursor`, percent-encoded as given; malformed cursors are 400).
//! * `GET /dids/{scope}?cursor=&limit=` — cursor-paginated per-scope DID
//!   listing (name-ordered); same `x-rucio-next-cursor` contract.
//!
//! Metadata & discovery surface (paper §2.2):
//! * `GET /dids/{scope}?filter=<meta-expr>` — cursor NDJSON of the DIDs
//!   matching a typed metadata filter (`datatype=RAW AND run>=358000
//!   AND name=data18*`); answered through the query planner (inverted
//!   index when an equality/range conjunct allows), malformed filters
//!   are 400.
//! * `GET /meta/{scope}/{name...}` — the DID's typed metadata map.
//! * `POST /meta/{scope}/{name...}` — set metadata pairs from a JSON
//!   object (JSON types map onto metadata types).

use std::sync::Arc;

use crate::common::error::{Result, RucioError};
use crate::core::accounts_api::Action;
use crate::core::metaexpr::{self, MetaValue};
use crate::core::replicas_api::ReplicaSpec;
use crate::core::rules_api::RuleSpec;
use crate::core::types::*;
use crate::core::Catalog;
use crate::httpd::{HttpServer, Request, Response, Router};
use crate::jsonx::Json;
use crate::mq::Broker;

/// Build the Rucio REST router over a shared catalog (+ broker for
/// trace ingestion).
pub fn build_router(catalog: Arc<Catalog>, broker: Broker) -> Router {
    let mut r = Router::new();

    r.get("/ping", {
        move |_req| Response::json(200, &Json::obj().with("version", "rucio-rs 0.1"))
    });

    // ---------------- auth (paper §4.1) ----------------
    let cat = catalog.clone();
    r.get("/auth/userpass", move |req| {
        let (Some(account), Some(user), Some(pass)) = (
            req.header("x-rucio-account"),
            req.header("x-rucio-username"),
            req.header("x-rucio-password"),
        ) else {
            return Response::error(&RucioError::CannotAuthenticate("missing headers".into()));
        };
        match cat.auth_userpass(account, user, pass) {
            Ok(t) => Response::new(200).with_header("x-rucio-auth-token", &t.token),
            Err(e) => Response::error(&e),
        }
    });
    let cat = catalog.clone();
    r.get("/auth/x509", move |req| {
        let (Some(account), Some(dn)) =
            (req.header("x-rucio-account"), req.header("x-rucio-client-dn"))
        else {
            return Response::error(&RucioError::CannotAuthenticate("missing headers".into()));
        };
        match cat.auth_x509(account, dn) {
            Ok(t) => Response::new(200).with_header("x-rucio-auth-token", &t.token),
            Err(e) => Response::error(&e),
        }
    });

    // ---------------- scopes ----------------
    let cat = catalog.clone();
    r.post("/scopes/{scope}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            cat.check_permission(&auth.account, Action::AddScope, None)?;
            let body = req.body_json().unwrap_or(Json::obj());
            let owner = body.opt_str("account").unwrap_or(&auth.account);
            // the new scope inherits the owner's VO — which must be the
            // caller's own unless the caller operates the instance
            if !auth.operator && cat.account_vo(owner)? != auth.vo {
                return Err(RucioError::AccessDenied(format!(
                    "cannot create a scope for {owner} outside VO {}",
                    auth.vo
                )));
            }
            cat.add_scope(req.param("scope")?, owner)?;
            Ok(Response::text(201, "Created"))
        })
    });
    let cat = catalog.clone();
    r.get("/scopes", move |req| {
        with_auth(&cat, req, |cat, auth| {
            // list is VO-filtered: foreign tenants' namespaces stay dark
            let scopes = if auth.operator {
                cat.list_scopes()
            } else {
                cat.scopes.filter_map(|s| (s.vo == auth.vo).then(|| s.name.clone()))
            };
            Ok(Response::ndjson(
                200,
                scopes.into_iter().map(|s| Json::obj().with("scope", s)),
            ))
        })
    });

    // ---------------- DIDs (paper §2.2) ----------------
    let cat = catalog.clone();
    r.post("/dids/{scope}/{name...}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let scope = req.param("scope")?;
            let name = req.param("name")?;
            let account = auth.account.as_str();
            cat.check_permission(account, Action::AddDid, Some(scope))?;
            let body = req.body_json()?;
            match body.opt_str("type").unwrap_or("FILE") {
                "FILE" => cat.add_file(
                    scope,
                    name,
                    account,
                    body.opt_u64("bytes").unwrap_or(0),
                    body.opt_str("adler32").unwrap_or(""),
                    body.opt_str("guid"),
                )?,
                "DATASET" => cat.add_dataset(scope, name, account)?,
                "CONTAINER" => cat.add_container(scope, name, account)?,
                other => {
                    return Err(RucioError::InvalidValue(format!("bad did type {other}")))
                }
            }
            Ok(Response::text(201, "Created"))
        })
    });
    let cat = catalog.clone();
    r.get("/dids/{scope}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let scope = req.param("scope")?;
            guard_scope(cat, auth, scope)?;
            let did_type = match req.query_get("type") {
                Some("FILE") => Some(DidType::File),
                Some("DATASET") => Some(DidType::Dataset),
                Some("CONTAINER") => Some(DidType::Container),
                _ => None,
            };
            // Discovery variant: a meta-expr filter answered through the
            // query planner, cursor-paginated (every page is a filtered
            // page of exactly `limit` matches until exhaustion).
            if let Some(filter) = req.query_get("filter") {
                let mut expr = metaexpr::parse(filter)?;
                if let Some(t) = did_type {
                    expr = metaexpr::MetaExpr::And(
                        Box::new(expr),
                        Box::new(metaexpr::MetaExpr::TypeIs(t)),
                    );
                }
                let limit = parse_limit(req);
                let (rows, next) =
                    cat.query_dids_page(scope, &expr, req.query_get("cursor"), limit);
                let resp = Response::ndjson(200, rows.iter().map(did_json));
                return Ok(with_next_cursor(resp, next));
            }
            // Cursor-paginated variant: name-ordered pages with a resume
            // cursor in x-rucio-next-cursor. The type filter applies to
            // each page, so a filtered page may carry fewer than `limit`
            // rows while the cursor still advances.
            if req.query_get("cursor").is_some() || req.query_get("limit").is_some() {
                let limit = parse_limit(req);
                let (rows, next) = cat.list_dids_page(scope, req.query_get("cursor"), limit);
                let items = rows
                    .iter()
                    .filter(|d| !d.suppressed)
                    .filter(|d| did_type.map(|t| d.did_type == t).unwrap_or(true))
                    .map(did_json);
                return Ok(with_next_cursor(Response::ndjson(200, items), next));
            }
            let items = cat
                .list_dids(scope, req.query_get("name"), did_type, false)
                .into_iter()
                .map(|d| did_json(&d));
            Ok(Response::ndjson(200, items))
        })
    });
    // Suffix routes must register before the bare DID route: dispatch is
    // first-match-wins and the greedy name tail would swallow the suffix.
    let cat = catalog.clone();
    r.get("/dids/{scope}/{name...}/rules", move |req| {
        with_auth(&cat, req, |cat, auth| {
            guard_scope(cat, auth, req.param("scope")?)?;
            let key = DidKey::new(req.param("scope")?, req.param("name")?);
            let items = cat.list_rules_for_did(&key).into_iter().map(|r| rule_json(&r));
            Ok(Response::ndjson(200, items))
        })
    });
    // Popularity / heat read-out (paper §6.1): the tracer-fed demand
    // signal behind the C3PO placement daemon, decayed to "now".
    let cat = catalog.clone();
    r.get("/dids/{scope}/{name...}/popularity", move |req| {
        with_auth(&cat, req, |cat, auth| {
            guard_scope(cat, auth, req.param("scope")?)?;
            let key = DidKey::new(req.param("scope")?, req.param("name")?);
            cat.get_did(&key)?;
            let now = cat.now();
            let pop = cat.popularity.get(&key);
            let mut j = Json::obj()
                .with("scope", key.scope.as_str())
                .with("name", key.name.as_str())
                .with("heat_score", cat.heat_score(&key, now))
                .with("heat_half_life_ms", cat.heat_half_life_ms())
                .with("accesses", pop.as_ref().map(|p| p.accesses).unwrap_or(0))
                .with(
                    "window_accesses",
                    pop.as_ref().map(|p| p.window_accesses).unwrap_or(0),
                );
            if let Some(p) = &pop {
                j = j.with("last_access", p.last_access);
            }
            Ok(Response::json(200, &j))
        })
    });
    let cat = catalog.clone();
    r.get("/dids/{scope}/{name...}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            guard_scope(cat, auth, req.param("scope")?)?;
            let key = DidKey::new(req.param("scope")?, req.param("name")?);
            let d = cat.get_did(&key)?;
            Ok(Response::json(200, &did_json(&d)))
        })
    });
    // DID metadata (own prefix: the DID routes' greedy name tail would
    // swallow a `/meta` suffix).
    let cat = catalog.clone();
    r.get("/meta/{scope}/{name...}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            guard_scope(cat, auth, req.param("scope")?)?;
            let key = DidKey::new(req.param("scope")?, req.param("name")?);
            let meta = cat.get_metadata(&key)?;
            let mut obj = Json::obj();
            for (k, v) in &meta {
                obj.set(k, meta_value_json(v));
            }
            Ok(Response::json(200, &obj))
        })
    });
    let cat = catalog.clone();
    r.post("/meta/{scope}/{name...}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let scope = req.param("scope")?;
            let key = DidKey::new(scope, req.param("name")?);
            cat.check_permission(&auth.account, Action::AddDid, Some(scope))?;
            let body = req.body_json()?;
            let obj = body
                .as_obj()
                .ok_or_else(|| RucioError::InvalidValue("metadata body must be an object".into()))?;
            let mut pairs = Vec::with_capacity(obj.len());
            for (k, v) in obj {
                pairs.push((k.clone(), json_to_meta_value(v)?));
            }
            cat.set_metadata_bulk(&key, pairs)?;
            Ok(Response::text(201, "Created"))
        })
    });
    let cat = catalog.clone();
    r.post("/attachments/{scope}/{name...}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let parent = DidKey::new(req.param("scope")?, req.param("name")?);
            cat.check_permission(&auth.account, Action::AttachDid, Some(&parent.scope))?;
            let body = req.body_json()?;
            let child = DidKey::new(body.req_str("child_scope")?, body.req_str("child_name")?);
            // both endpoints of an attachment must live in the caller's VO
            guard_scope(cat, auth, &child.scope)?;
            cat.attach(&parent, &child)?;
            // async subscription matching happens via the transmogrifier;
            // for interactive use we match synchronously too (idempotent)
            let _ = cat.match_subscriptions(&parent);
            Ok(Response::text(201, "Created"))
        })
    });

    // ---------------- replicas ----------------
    // Bulk registration: one batched catalog commit for the whole set
    // (registered before the param routes so the literal path wins).
    let cat = catalog.clone();
    r.post("/replicas/bulk", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let body = req.body_json()?;
            let rse = body.req_str("rse")?;
            let arr = body
                .get("replicas")
                .and_then(Json::as_arr)
                .ok_or_else(|| RucioError::InvalidValue("replicas array required".into()))?;
            let mut specs = Vec::with_capacity(arr.len());
            for item in arr {
                let did = DidKey::new(item.req_str("scope")?, item.req_str("name")?);
                guard_scope(cat, auth, &did.scope)?;
                let state = match item.opt_str("state") {
                    Some("COPYING") => ReplicaState::Copying,
                    _ => ReplicaState::Available,
                };
                let mut spec = ReplicaSpec::new(did, state);
                if let Some(pfn) = item.opt_str("pfn") {
                    spec = spec.with_pfn(pfn);
                }
                specs.push(spec);
            }
            let added = cat.add_replicas_bulk(rse, &specs)?;
            Ok(Response::json(201, &Json::obj().with("added", added as u64)))
        })
    });
    // Cursor-paginated NDJSON list of all replicas.
    let cat = catalog.clone();
    r.get("/replicas", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let limit = parse_limit(req);
            let cursor = match req.query_get("cursor") {
                Some(raw) => Some(decode_replica_cursor(raw).ok_or_else(|| {
                    RucioError::InvalidValue("malformed replica cursor".into())
                })?),
                None => None,
            };
            let page = cat.replicas.scan_page(cursor.as_ref(), limit);
            // VO filter applies per page (like the DID type filter): a
            // filtered page may be short while the cursor still advances
            let vos = ScopeVoCache::new(cat);
            let resp = Response::ndjson(
                200,
                page.rows
                    .iter()
                    .filter(|rep| vos.visible(auth, &rep.did.scope))
                    .map(|rep| {
                        Json::obj()
                            .with("rse", rep.rse.as_str())
                            .with("scope", rep.did.scope.as_str())
                            .with("name", rep.did.name.as_str())
                            .with("pfn", rep.pfn.as_str())
                            .with("bytes", rep.bytes)
                            .with("state", rep.state.as_str())
                    }),
            );
            let next = page
                .next_cursor
                .as_ref()
                .map(|(rse, did)| encode_replica_cursor(rse, did));
            Ok(with_next_cursor(resp, next))
        })
    });
    let cat = catalog.clone();
    r.get("/replicas/{scope}/{name...}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            guard_scope(cat, auth, req.param("scope")?)?;
            let key = DidKey::new(req.param("scope")?, req.param("name")?);
            cat.get_did(&key)?;
            let items = cat.list_replicas(&key).into_iter().map(|r| {
                Json::obj()
                    .with("rse", r.rse.as_str())
                    .with("pfn", r.pfn.as_str())
                    .with("bytes", r.bytes)
                    .with("state", r.state.as_str())
            });
            Ok(Response::ndjson(200, items))
        })
    });
    let cat = catalog.clone();
    r.post("/replicas/{rse}/{scope}/{name...}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            guard_scope(cat, auth, req.param("scope")?)?;
            let key = DidKey::new(req.param("scope")?, req.param("name")?);
            let body = req.body_json().unwrap_or(Json::obj());
            let rep = cat.add_replica(
                req.param("rse")?,
                &key,
                ReplicaState::Available,
                body.opt_str("pfn"),
            )?;
            Ok(Response::json(201, &Json::obj().with("pfn", rep.pfn.as_str())))
        })
    });

    // ---------------- rules (paper §2.5) ----------------
    // Bulk creation: each rule's locks + transfer requests land as
    // batched commits in the core. All specs are parsed up front; if any
    // rule fails mid-batch the already-created ones are rolled back
    // (delete_rule fully unwinds locks + usage), so the call is atomic.
    let cat = catalog.clone();
    r.post("/rules/bulk", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let account = auth.account.as_str();
            cat.check_permission(account, Action::AddRule, None)?;
            let body = req.body_json()?;
            let arr = body
                .get("rules")
                .and_then(Json::as_arr)
                .ok_or_else(|| RucioError::InvalidValue("rules array required".into()))?;
            let mut specs = Vec::with_capacity(arr.len());
            for item in arr {
                let did = DidKey::new(item.req_str("scope")?, item.req_str("name")?);
                guard_scope(cat, auth, &did.scope)?;
                let mut spec = RuleSpec::new(
                    account,
                    did,
                    item.req_str("rse_expression")?,
                    item.opt_u64("copies").unwrap_or(1) as u32,
                );
                if let Some(l) = item.opt_i64("lifetime_ms") {
                    spec = spec.with_lifetime(l);
                }
                if let Some(a) = item.opt_str("activity") {
                    spec = spec.with_activity(a);
                }
                specs.push(spec);
            }
            let ids: Vec<Json> = cat.add_rules_bulk(specs)?.into_iter().map(Json::from).collect();
            Ok(Response::json(201, &Json::obj().with("rule_ids", Json::Arr(ids))))
        })
    });
    // Cursor-paginated NDJSON list of all rules (id order).
    let cat = catalog.clone();
    r.get("/rules", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let limit = parse_limit(req);
            let cursor = parse_id_cursor(req, "rule")?;
            let page = cat.rules.scan_page(cursor.as_ref(), limit);
            let vos = ScopeVoCache::new(cat);
            let resp = Response::ndjson(
                200,
                page.rows
                    .iter()
                    .filter(|r| vos.visible(auth, &r.did.scope))
                    .map(rule_json),
            );
            Ok(with_next_cursor(resp, page.next_cursor.map(|n| n.to_string())))
        })
    });
    let cat = catalog.clone();
    r.post("/rules", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let account = auth.account.as_str();
            cat.check_permission(account, Action::AddRule, None)?;
            let body = req.body_json()?;
            let did = DidKey::new(body.req_str("scope")?, body.req_str("name")?);
            guard_scope(cat, auth, &did.scope)?;
            let mut spec = RuleSpec::new(
                account,
                did,
                body.req_str("rse_expression")?,
                body.opt_u64("copies").unwrap_or(1) as u32,
            );
            if let Some(l) = body.opt_i64("lifetime_ms") {
                spec = spec.with_lifetime(l);
            }
            if let Some(a) = body.opt_str("activity") {
                spec = spec.with_activity(a);
            }
            let id = cat.add_rule(spec)?;
            Ok(Response::json(201, &Json::obj().with("rule_id", id)))
        })
    });
    let cat = catalog.clone();
    r.get("/rules/{id}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let id: u64 = req
                .param("id")?
                .parse()
                .map_err(|_| RucioError::InvalidValue("bad rule id".into()))?;
            let rule = cat.get_rule(id)?;
            guard_scope(cat, auth, &rule.did.scope)?;
            Ok(Response::json(200, &rule_json(&rule)))
        })
    });
    let cat = catalog.clone();
    r.delete("/rules/{id}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let id: u64 = req
                .param("id")?
                .parse()
                .map_err(|_| RucioError::InvalidValue("bad rule id".into()))?;
            let rule = cat.get_rule(id)?;
            guard_scope(cat, auth, &rule.did.scope)?;
            let acc = cat.get_account(&auth.account)?;
            if rule.account != auth.account && !acc.admin {
                return Err(RucioError::AccessDenied(format!(
                    "{} does not own rule {id}",
                    auth.account
                )));
            }
            cat.delete_rule(id)?;
            Ok(Response::text(200, "OK"))
        })
    });
    // ---------------- RSEs (admin) ----------------
    let cat = catalog.clone();
    r.post("/rses/{rse}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            cat.check_permission(&auth.account, Action::AddRse, None)?;
            let name = req.param("rse")?;
            let body = req.body_json().unwrap_or(Json::obj());
            let mut rse = crate::core::rse::Rse::new(name, cat.now());
            if body.opt_bool("tape").unwrap_or(false) {
                rse = rse.with_tape();
            }
            if let Some(attrs) = body.get("attributes").and_then(Json::as_obj) {
                for (k, v) in attrs {
                    if let Some(s) = v.as_str() {
                        rse = rse.with_attr(k, s);
                    }
                }
            }
            cat.add_rse(rse)?;
            Ok(Response::text(201, "Created"))
        })
    });
    let cat = catalog.clone();
    r.get("/rses", move |req| {
        // RSEs are shared data-lake infrastructure, visible to every VO
        with_auth(&cat, req, |cat, _auth| {
            let items = cat.list_rses().into_iter().map(|r| {
                Json::obj()
                    .with("rse", r.name.as_str())
                    .with("tape", r.is_tape)
                    .with("deterministic", r.path_algorithm != crate::core::rse::PathAlgorithm::NonDeterministic)
            });
            Ok(Response::ndjson(200, items))
        })
    });
    // Flag an RSE for decommissioning: the BB8 daemon drains it in the
    // background (pending → draining → done). Admin-only like /boost —
    // and instance-operator only, because an RSE is shared
    // infrastructure across every tenant VO.
    let cat = catalog.clone();
    r.post("/rses/{rse}/decommission", move |req| {
        with_auth(&cat, req, |cat, auth| {
            if !cat.get_account(&auth.account)?.admin {
                return Err(RucioError::AccessDenied(format!(
                    "{} may not decommission RSEs",
                    auth.account
                )));
            }
            if !auth.operator {
                return Err(RucioError::AccessDenied(format!(
                    "decommissioning shared infrastructure takes the instance \
                     operator; {} administers VO {} only",
                    auth.account, auth.vo
                )));
            }
            let name = req.param("rse")?;
            let rse = cat.get_rse(name)?;
            let state = match rse.attr("decommission") {
                // already on its way (or done): report, never restart
                Some(s) => s.to_string(),
                None => {
                    cat.set_rse_attribute(
                        name,
                        "decommission",
                        crate::daemons::bb8::DECOM_PENDING,
                    )?;
                    crate::daemons::bb8::DECOM_PENDING.to_string()
                }
            };
            Ok(Response::json(
                202,
                &Json::obj().with("rse", name).with("decommission", state),
            ))
        })
    });

    // ---------------- rebalancing (paper §6.2) ----------------
    // Operator view of live rebalancing: every parent→child rule move
    // still in flight plus the decommission ledger, derived entirely
    // from the catalog — no daemon handle involved.
    let cat = catalog.clone();
    r.get("/rebalance/status", move |req| {
        with_auth(&cat, req, |cat, auth| {
            if !auth.operator {
                return Err(RucioError::AccessDenied(format!(
                    "rebalance status spans every tenant; {} is scoped to VO {}",
                    auth.account, auth.vo
                )));
            }
            let parents = cat.rules.scan(|r| r.child_rule.is_some());
            let mut moves = Vec::new();
            let mut bytes_pending = 0u64;
            for parent in &parents {
                let child_id = parent.child_rule.unwrap();
                let Some(child) = cat.get_rule(child_id).ok() else { continue };
                if child.state == RuleState::Ok {
                    continue; // landed; awaiting finalize_moves
                }
                let mut pending = 0u64;
                for lock_key in cat.locks_by_rule.get(&child_id) {
                    let Some(lock) = cat.locks.get(&lock_key) else { continue };
                    if lock.state != LockState::Ok {
                        pending += lock.bytes;
                    }
                }
                bytes_pending += pending;
                moves.push(
                    Json::obj()
                        .with("rule_id", parent.id)
                        .with("child_rule_id", child_id)
                        .with("scope", parent.did.scope.as_str())
                        .with("name", parent.did.name.as_str())
                        .with("from", parent.rse_expression.as_str())
                        .with("to", child.rse_expression.as_str())
                        .with("bytes_pending", pending),
                );
            }
            let decommissions: Vec<Json> = cat
                .list_rses()
                .into_iter()
                .filter_map(|r| {
                    r.attr("decommission").map(|s| {
                        Json::obj().with("rse", r.name.as_str()).with("state", s)
                    })
                })
                .collect();
            Ok(Response::json(
                200,
                &Json::obj()
                    .with("live_moves", moves.len())
                    .with("bytes_pending", bytes_pending)
                    .with("moves", Json::Arr(moves))
                    .with("decommissions", Json::Arr(decommissions)),
            ))
        })
    });

    // ---------------- accounts / usage ----------------
    let cat = catalog.clone();
    r.post("/accounts/{name}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            cat.check_permission(&auth.account, Action::AddAccount, None)?;
            let body = req.body_json()?;
            let t = match body.opt_str("type").unwrap_or("USER") {
                "GROUP" => AccountType::Group,
                "SERVICE" => AccountType::Service,
                _ => AccountType::User,
            };
            // a VO admin provisions accounts inside its own VO only; the
            // instance operator may name any VO in the body
            let vo = match body.opt_str("vo") {
                Some(v) if auth.operator => v.to_string(),
                Some(v) if v != auth.vo => {
                    return Err(RucioError::AccessDenied(format!(
                        "{} may not create accounts in VO {v}",
                        auth.account
                    )))
                }
                _ => auth.vo.clone(),
            };
            cat.add_account_vo(req.param("name")?, t, body.opt_str("email").unwrap_or(""), &vo)?;
            if let Some(pw) = body.opt_str("password") {
                cat.add_identity(req.param("name")?, AuthType::UserPass, req.param("name")?, Some(pw))?;
            }
            Ok(Response::text(201, "Created"))
        })
    });
    let cat = catalog.clone();
    r.get("/accounts/{name}/usage/{rse}", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let name = req.param("name")?;
            // usage is tenant-private: foreign-VO accounts are invisible
            if !auth.operator && cat.account_vo(name).ok().as_deref() != Some(auth.vo.as_str()) {
                return Err(RucioError::AccessDenied(format!(
                    "account {name} is outside VO {}",
                    auth.vo
                )));
            }
            let u = cat.get_account_usage(name, req.param("rse")?);
            Ok(Response::json(
                200,
                &Json::obj().with("bytes", u.bytes).with("files", u.files),
            ))
        })
    });

    // ---------------- transfer requests (paper §4.2 / Fig 6) ----------------
    // Cursor-paginated NDJSON over the request table (id order), with
    // per-page state/activity filters — the operator's view into the
    // admission pipeline (WAITING → QUEUED → SUBMITTED → DONE/FAILED).
    let cat = catalog.clone();
    r.get("/requests", move |req| {
        with_auth(&cat, req, |cat, auth| {
            let limit = parse_limit(req);
            let cursor = parse_id_cursor(req, "request")?;
            let state = match req.query_get("state") {
                Some(raw) => Some(RequestState::parse(raw).ok_or_else(|| {
                    RucioError::InvalidValue(format!("unknown request state {raw}"))
                })?),
                None => None,
            };
            let activity = req.query_get("activity");
            let page = cat.requests.scan_page(cursor.as_ref(), limit);
            let vos = ScopeVoCache::new(cat);
            let items = page
                .rows
                .iter()
                .filter(|t| state.map(|s| t.state == s).unwrap_or(true))
                .filter(|t| activity.map(|a| t.activity == a).unwrap_or(true))
                .filter(|t| vos.visible(auth, &t.did.scope))
                .map(request_json);
            let resp = Response::ndjson(200, items);
            Ok(with_next_cursor(resp, page.next_cursor.map(|n| n.to_string())))
        })
    });
    // Boost: raise a request's scheduling priority; a WAITING request
    // bypasses the throttler immediately. Admin-only — boosting reshapes
    // scheduling for everyone sharing the link.
    let cat = catalog.clone();
    r.post("/requests/{id}/boost", move |req| {
        with_auth(&cat, req, |cat, auth| {
            if !cat.get_account(&auth.account)?.admin {
                return Err(RucioError::AccessDenied(format!(
                    "{} may not boost transfer requests",
                    auth.account
                )));
            }
            let id: u64 = req
                .param("id")?
                .parse()
                .map_err(|_| RucioError::InvalidValue("bad request id".into()))?;
            // a VO admin reshapes scheduling for its own tenant only
            if let Some(t) = cat.requests.get(&id) {
                guard_scope(cat, auth, &t.did.scope)?;
            }
            let boosted = cat.boost_request(id)?;
            Ok(Response::json(200, &request_json(&boosted)))
        })
    });

    // ---------------- traces (paper §4.6) ----------------
    let cat = catalog.clone();
    let brk = broker.clone();
    r.post("/traces", move |req| {
        // traces are fire-and-forget; auth optional like upstream
        let Ok(body) = req.body_json() else {
            return Response::error(&RucioError::JsonError("bad trace".into()));
        };
        if let (Some(rse), Some(scope), Some(name)) = (
            body.opt_str("rse"),
            body.opt_str("scope"),
            body.opt_str("name"),
        ) {
            crate::daemons::tracer::emit_trace(
                &brk,
                cat.now(),
                body.opt_str("event").unwrap_or("download"),
                rse,
                scope,
                name,
            );
        }
        Response::text(201, "Created")
    });

    r
}

/// Page size for cursor list routes: `limit` query param, capped so one
/// response stays bounded.
fn parse_limit(req: &Request) -> usize {
    req.query_get("limit")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000)
        .clamp(1, 10_000)
}

/// Every paginated list route resumes through the same header: the
/// opaque cursor crosses the wire percent-encoded in
/// `x-rucio-next-cursor` and comes back verbatim as `cursor`.
fn with_next_cursor(resp: Response, next: Option<String>) -> Response {
    match next {
        Some(n) => resp.with_header("x-rucio-next-cursor", &crate::httpd::percent_encode(&n)),
        None => resp,
    }
}

/// Numeric-id cursor shared by `/rules` and `/requests`: the row id the
/// previous page stopped at; anything else is a 400.
fn parse_id_cursor(req: &Request, what: &str) -> Result<Option<u64>> {
    match req.query_get("cursor") {
        Some(raw) => raw
            .parse()
            .map(Some)
            .map_err(|_| RucioError::InvalidValue(format!("malformed {what} cursor"))),
        None => Ok(None),
    }
}

/// Replica-table cursors cross the wire as `rse␞scope␞name` (unit
/// separators percent-encoded by the router contract).
fn encode_replica_cursor(rse: &str, did: &DidKey) -> String {
    format!("{rse}\u{1e}{}\u{1e}{}", did.scope, did.name)
}

fn decode_replica_cursor(s: &str) -> Option<(String, DidKey)> {
    let mut parts = s.splitn(3, '\u{1e}');
    let rse = parts.next()?;
    let scope = parts.next()?;
    let name = parts.next()?;
    Some((rse.to_string(), DidKey::new(scope, name)))
}

/// Authenticated request context: the account, its VO, and whether the
/// caller operates the whole instance (default-VO admin) and may cross
/// tenant boundaries.
pub struct Auth {
    pub account: String,
    pub vo: String,
    pub operator: bool,
}

/// Wrap a handler with token validation (§4.1: "each subsequent operation
/// against any of the REST servers needs the valid X-Rucio-Auth-Token").
/// The token pins the VO; every route receives it for tenant isolation.
fn with_auth<F>(catalog: &Arc<Catalog>, req: &Request, f: F) -> Response
where
    F: FnOnce(&Catalog, &Auth) -> Result<Response>,
{
    let Some(token) = req.header("x-rucio-auth-token") else {
        return Response::error(&RucioError::CannotAuthenticate("missing token".into()));
    };
    match catalog.validate_token_vo(token) {
        Ok((account, vo)) => {
            let operator = vo == DEFAULT_VO
                && catalog.get_account(&account).map(|a| a.admin).unwrap_or(false);
            let auth = Auth { account, vo, operator };
            match f(catalog, &auth) {
                Ok(resp) => resp,
                Err(e) => Response::error(&e),
            }
        }
        Err(e) => Response::error(&e),
    }
}

/// Tenant guard for scope-addressed routes: a scope owned by a foreign
/// VO is off limits (403) unless the caller is an instance operator.
/// Unknown scopes fall through so the route's own lookup reports 404 —
/// nonexistence leaks nothing.
fn guard_scope(cat: &Catalog, auth: &Auth, scope: &str) -> Result<()> {
    if auth.operator {
        return Ok(());
    }
    match cat.scopes.get(&scope.to_string()) {
        Some(s) if s.vo != auth.vo => Err(RucioError::AccessDenied(format!(
            "scope {scope} belongs to VO {}, caller is in VO {}",
            s.vo, auth.vo
        ))),
        _ => Ok(()),
    }
}

/// Memoised scope → VO resolution for row filtering on the global list
/// routes (replicas, rules, requests stream thousands of rows per page;
/// each distinct scope is resolved once).
struct ScopeVoCache<'a> {
    cat: &'a Catalog,
    cache: std::cell::RefCell<std::collections::BTreeMap<String, Option<String>>>,
}

impl<'a> ScopeVoCache<'a> {
    fn new(cat: &'a Catalog) -> Self {
        Self { cat, cache: std::cell::RefCell::new(std::collections::BTreeMap::new()) }
    }

    /// Is a row under `scope` visible to the caller? Rows whose scope no
    /// longer resolves stay visible to operators only.
    fn visible(&self, auth: &Auth, scope: &str) -> bool {
        if auth.operator {
            return true;
        }
        let mut cache = self.cache.borrow_mut();
        let vo = cache
            .entry(scope.to_string())
            .or_insert_with(|| self.cat.scopes.get(&scope.to_string()).map(|s| s.vo))
            .clone();
        vo.as_deref() == Some(auth.vo.as_str())
    }
}

/// Typed metadata → JSON (ints stay integral; JSON numbers are f64, so
/// integer fidelity holds for |n| ≤ 2^53 — DID metadata in practice).
fn meta_value_json(v: &MetaValue) -> Json {
    match v {
        MetaValue::Bool(b) => Json::Bool(*b),
        MetaValue::Int(i) => Json::Num(*i as f64),
        MetaValue::Float(f) => Json::Num(*f),
        MetaValue::Str(s) => Json::Str(s.clone()),
    }
}

/// JSON → typed metadata: JSON types carry the intent directly (a JSON
/// string is a string even if it looks numeric — no lexical guessing on
/// this surface).
fn json_to_meta_value(v: &Json) -> Result<MetaValue> {
    match v {
        Json::Bool(b) => Ok(MetaValue::Bool(*b)),
        Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Ok(MetaValue::Int(*n as i64)),
        Json::Num(n) => Ok(MetaValue::Float(*n)),
        Json::Str(s) => Ok(MetaValue::Str(s.clone())),
        other => Err(RucioError::InvalidValue(format!(
            "metadata values must be scalar, got {other:?}"
        ))),
    }
}

fn did_json(d: &Did) -> Json {
    Json::obj()
        .with("scope", d.key.scope.as_str())
        .with("name", d.key.name.as_str())
        .with("type", d.did_type.as_str())
        .with("account", d.account.as_str())
        .with("bytes", d.bytes)
        .with("open", d.open)
        .with("monotonic", d.monotonic)
        .with("availability", d.availability.as_str())
}

fn request_json(t: &TransferRequest) -> Json {
    let mut j = Json::obj()
        .with("id", t.id)
        .with("scope", t.did.scope.as_str())
        .with("name", t.did.name.as_str())
        .with("dst_rse", t.dst_rse.as_str())
        .with("rule_id", t.rule_id)
        .with("activity", t.activity.as_str())
        .with("state", t.state.as_str())
        .with("priority", t.priority as u64)
        .with("attempts", t.attempts as u64)
        .with("bytes", t.bytes);
    if let Some(src) = &t.src_rse {
        j = j.with("src_rse", src.as_str());
    }
    if let Some(path) = &t.path {
        j = j.with(
            "path",
            Json::Arr(path.iter().map(|p| Json::Str(p.clone())).collect()),
        );
    }
    j
}

fn rule_json(r: &Rule) -> Json {
    Json::obj()
        .with("id", r.id)
        .with("account", r.account.as_str())
        .with("scope", r.did.scope.as_str())
        .with("name", r.did.name.as_str())
        .with("rse_expression", r.rse_expression.as_str())
        .with("copies", r.copies as u64)
        .with("state", r.state.as_str())
        .with("locks_ok", r.locks_ok as u64)
        .with("locks_replicating", r.locks_replicating as u64)
        .with("locks_stuck", r.locks_stuck as u64)
}

/// Start the server on `bind` with `n_workers` threads.
pub fn serve(
    catalog: Arc<Catalog>,
    broker: Broker,
    bind: &str,
    n_workers: usize,
) -> Result<HttpServer> {
    let router = build_router(catalog, broker);
    HttpServer::start(bind, router, n_workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::RucioClient;

    fn server() -> (HttpServer, Arc<Catalog>) {
        let catalog = Arc::new(Catalog::new_for_tests());
        catalog.add_account("alice", AccountType::User, "a@x").unwrap();
        catalog
            .add_identity("alice", AuthType::UserPass, "alice", Some("pw"))
            .unwrap();
        catalog.add_identity("root", AuthType::UserPass, "root", Some("rootpw")).unwrap();
        catalog.add_rse(crate::core::rse::Rse::new("X-DISK", 0)).unwrap();
        let broker = Broker::new();
        let srv = serve(catalog.clone(), broker, "127.0.0.1:0", 2).unwrap();
        (srv, catalog)
    }

    #[test]
    fn full_client_round_trip() {
        let (srv, _cat) = server();
        let client = RucioClient::connect(&srv.url(), "alice", "alice", "pw").unwrap();
        // create DIDs in own scope
        client.add_dataset("user.alice", "myds").unwrap();
        client
            .add_file("user.alice", "f1", 1234, "aabbccdd")
            .unwrap();
        client.attach("user.alice", "myds", "user.alice", "f1").unwrap();
        let dids = client.list_dids("user.alice").unwrap();
        assert_eq!(dids.len(), 2);
        // place a rule
        let rule_id = client
            .add_rule("user.alice", "myds", "X-DISK", 1, None)
            .unwrap();
        let rule = client.get_rule(rule_id).unwrap();
        assert_eq!(rule.req_str("state").unwrap(), "REPLICATING");
        // replicas listed
        let reps = client.list_replicas("user.alice", "f1").unwrap();
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].req_str("state").unwrap(), "COPYING");
    }

    #[test]
    fn auth_rejections() {
        let (srv, _cat) = server();
        // wrong password
        assert!(RucioClient::connect(&srv.url(), "alice", "alice", "nope").is_err());
        // missing token
        let raw = crate::httpd::HttpClient::new(&srv.url());
        let resp = raw.get("/dids/user.alice").unwrap();
        assert_eq!(resp.status, 401);
        // garbage token
        raw.set_header("x-rucio-auth-token", "forged");
        assert_eq!(raw.get("/dids/user.alice").unwrap().status, 401);
    }

    #[test]
    fn permissions_enforced_over_http() {
        let (srv, _cat) = server();
        let alice = RucioClient::connect(&srv.url(), "alice", "alice", "pw").unwrap();
        // alice cannot write another scope
        assert!(alice.add_dataset("root", "nope").is_err());
        // alice cannot create RSEs
        assert!(alice.add_rse("EVIL-RSE", false).is_err());
        // root can
        let root = RucioClient::connect(&srv.url(), "root", "root", "rootpw").unwrap();
        root.add_rse("NEW-RSE", true).unwrap();
        let rses = root.list_rses().unwrap();
        assert_eq!(rses.len(), 2);
    }

    #[test]
    fn rule_delete_ownership() {
        let (srv, cat) = server();
        let alice = RucioClient::connect(&srv.url(), "alice", "alice", "pw").unwrap();
        alice.add_file("user.alice", "g1", 10, "x").unwrap();
        let rid = alice.add_rule("user.alice", "g1", "X-DISK", 1, None).unwrap();
        // root may delete anyone's rule; alice may delete her own
        alice.delete_rule(rid).unwrap();
        assert!(cat.get_rule(rid).is_err());
    }

    #[test]
    fn bulk_replicas_and_rules_round_trip() {
        let (srv, cat) = server();
        let alice = RucioClient::connect(&srv.url(), "alice", "alice", "pw").unwrap();
        let mut dids = Vec::new();
        for i in 0..30 {
            let name = format!("bulk{i:03}");
            alice.add_file("user.alice", &name, 100, "aabbccdd").unwrap();
            dids.push(("user.alice".to_string(), name));
        }
        // one request registers the whole batch
        let added = alice.register_replicas_bulk("X-DISK", &dids).unwrap();
        assert_eq!(added, 30);
        assert_eq!(cat.replicas.len(), 30);
        // a second identical call is a duplicate batch → atomic failure
        assert!(alice.register_replicas_bulk("X-DISK", &dids).is_err());
        assert_eq!(cat.replicas.len(), 30);
        // bulk rules over the pre-placed replicas: instantly OK
        let specs: Vec<(String, String, String, u32)> = dids
            .iter()
            .take(10)
            .map(|(s, n)| (s.clone(), n.clone(), "X-DISK".to_string(), 1))
            .collect();
        let ids = alice.add_rules_bulk(&specs).unwrap();
        assert_eq!(ids.len(), 10);
        for id in &ids {
            let rule = alice.get_rule(*id).unwrap();
            assert_eq!(rule.req_str("state").unwrap(), "OK");
        }
    }

    #[test]
    fn cursor_paginated_lists() {
        let (srv, cat) = server();
        let alice = RucioClient::connect(&srv.url(), "alice", "alice", "pw").unwrap();
        let mut dids = Vec::new();
        for i in 0..25 {
            let name = format!("page{i:03}");
            alice.add_file("user.alice", &name, 10, "x").unwrap();
            dids.push(("user.alice".to_string(), name));
        }
        alice.register_replicas_bulk("X-DISK", &dids).unwrap();

        // paged DID walk covers the scope exactly once, in name order
        let mut names = Vec::new();
        let mut cursor: Option<String> = None;
        loop {
            let (rows, next) =
                alice.list_dids_page("user.alice", cursor.as_deref(), 10).unwrap();
            names.extend(rows.iter().map(|j| j.req_str("name").unwrap().to_string()));
            match next {
                Some(c) => cursor = Some(c),
                None => break,
            }
        }
        let expect: Vec<String> = (0..25).map(|i| format!("page{i:03}")).collect();
        assert_eq!(names, expect);

        // paged replica walk sees every replica exactly once
        let mut seen = 0;
        let mut cursor: Option<String> = None;
        let mut pages = 0;
        loop {
            let (rows, next) = alice.list_replicas_page(cursor.as_deref(), 7).unwrap();
            seen += rows.len();
            pages += 1;
            match next {
                Some(c) => cursor = Some(c),
                None => break,
            }
            assert!(pages < 50, "cursor must advance");
        }
        assert_eq!(seen as usize, cat.replicas.len());
        assert_eq!(pages, 4, "25 replicas / 7 per page");
    }

    #[test]
    fn metadata_filter_discovery_over_http() {
        let (srv, cat) = server();
        let alice = RucioClient::connect(&srv.url(), "alice", "alice", "pw").unwrap();
        for i in 0..20 {
            let name = format!("ds{i:03}");
            alice.add_dataset("user.alice", &name).unwrap();
            alice
                .set_metadata(
                    "user.alice",
                    &name,
                    &Json::obj()
                        .with("datatype", if i % 2 == 0 { "RAW" } else { "AOD" })
                        .with("run", 358000 + i as u64),
                )
                .unwrap();
        }
        // typed metadata round-trips through GET /meta
        let meta = alice.get_metadata("user.alice", "ds003").unwrap();
        assert_eq!(meta.req_str("datatype").unwrap(), "AOD");
        assert_eq!(meta.get("run").and_then(Json::as_i64), Some(358003));
        assert_eq!(
            cat.get_metadata(&DidKey::new("user.alice", "ds003")).unwrap()["run"],
            crate::core::metaexpr::MetaValue::Int(358003)
        );

        // filtered discovery: equality + run window, cursor-paged
        let filter = "datatype=RAW AND run>=358008 AND run<358016";
        let mut names = Vec::new();
        let mut cursor: Option<String> = None;
        let mut pages = 0;
        loop {
            let (rows, next) = alice
                .list_dids_filter_page("user.alice", filter, cursor.as_deref(), 3)
                .unwrap();
            names.extend(rows.iter().map(|j| j.req_str("name").unwrap().to_string()));
            pages += 1;
            match next {
                Some(c) => cursor = Some(c),
                None => break,
            }
            assert!(pages < 20);
        }
        // runs 358008..358015, even offsets → ds008 ds010 ds012 ds014
        let expect: Vec<String> = (8..16).step_by(2).map(|i| format!("ds{i:03}")).collect();
        assert_eq!(names, expect);
        assert_eq!(pages, 2, "4 matches / 3 per page + exhaustion");
        // the planner answered from the inverted index
        assert!(cat.metrics.counter("dids.query.indexed") >= 1);

        // malformed filter is a 400, not a 500
        let raw = crate::httpd::HttpClient::new(&srv.url());
        let tok = alice.token().to_string();
        raw.set_header("x-rucio-auth-token", &tok);
        let resp = raw.get("/dids/user.alice?filter=run%3E%3DRAW").unwrap();
        assert_eq!(resp.status, 400);
        // non-scalar metadata value rejected
        let resp = raw
            .post_json(
                "/meta/user.alice/ds000",
                &Json::obj().with("bad", Json::Arr(vec![Json::Num(1.0)])),
            )
            .unwrap();
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn request_listing_and_boost_over_http() {
        let (srv, cat) = server();
        let alice = RucioClient::connect(&srv.url(), "alice", "alice", "pw").unwrap();
        // rules without replicas → queued transfer requests
        for i in 0..5 {
            let name = format!("req{i}");
            alice.add_file("user.alice", &name, 100, "aabbccdd").unwrap();
            alice.add_rule("user.alice", &name, "X-DISK", 1, None).unwrap();
        }
        assert_eq!(cat.requests.len(), 5);
        let raw = crate::httpd::HttpClient::new(&srv.url());
        let tok = alice.token().to_string();
        raw.set_header("x-rucio-auth-token", &tok);

        // cursor-paged NDJSON walk with a state filter
        let mut seen = 0;
        let mut url = "/requests?state=QUEUED&limit=2".to_string();
        let mut pages = 0;
        loop {
            let resp = raw.get(&url).unwrap();
            assert_eq!(resp.status, 200);
            for j in resp.body_ndjson().unwrap() {
                assert_eq!(j.req_str("state").unwrap(), "QUEUED");
                assert_eq!(j.req_str("dst_rse").unwrap(), "X-DISK");
                seen += 1;
            }
            pages += 1;
            match resp.header("x-rucio-next-cursor") {
                Some(c) => url = format!("/requests?state=QUEUED&limit=2&cursor={c}"),
                None => break,
            }
            assert!(pages < 10, "cursor must advance");
        }
        assert_eq!(seen, 5);

        // activity filter excludes everything (workload used the default)
        let resp = raw.get("/requests?activity=NoSuchActivity").unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.body_ndjson().unwrap().is_empty());
        // malformed state / cursor → 400
        assert_eq!(raw.get("/requests?state=BOGUS").unwrap().status, 400);
        assert_eq!(raw.get("/requests?cursor=xyz").unwrap().status, 400);

        // boost: alice is denied, root reshapes scheduling
        let req_id = cat.requests.scan(|_| true)[0].id;
        let resp = raw
            .post_json(&format!("/requests/{req_id}/boost"), &Json::obj())
            .unwrap();
        assert_eq!(resp.status, 403, "boost is admin-only");
        let root = RucioClient::connect(&srv.url(), "root", "root", "rootpw").unwrap();
        let rootraw = crate::httpd::HttpClient::new(&srv.url());
        let roottok = root.token().to_string();
        rootraw.set_header("x-rucio-auth-token", &roottok);
        let resp = rootraw
            .post_json(&format!("/requests/{req_id}/boost"), &Json::obj())
            .unwrap();
        assert_eq!(resp.status, 200);
        let j = resp.body_json().unwrap();
        assert_eq!(j.req_u64("priority").unwrap(), PRIORITY_BOOSTED as u64);
        assert_eq!(
            cat.requests.get(&req_id).unwrap().priority,
            PRIORITY_BOOSTED
        );
        // unknown id → 404
        let resp = rootraw.post_json("/requests/999999/boost", &Json::obj()).unwrap();
        assert_eq!(resp.status, 404);
    }

    #[test]
    fn traces_reach_broker() {
        let (srv, cat) = server();
        let broker = Broker::new();
        // rebuild server with our broker handle to observe
        drop(srv);
        let srv = serve(cat.clone(), broker.clone(), "127.0.0.1:0", 2).unwrap();
        let sub = broker.subscribe("traces", None);
        let raw = crate::httpd::HttpClient::new(&srv.url());
        let resp = raw
            .post_json(
                "/traces",
                &Json::obj()
                    .with("event", "download")
                    .with("rse", "X-DISK")
                    .with("scope", "user.alice")
                    .with("name", "f1"),
            )
            .unwrap();
        assert_eq!(resp.status, 201);
        assert_eq!(broker.poll("traces", sub, 10).len(), 1);
    }
}
