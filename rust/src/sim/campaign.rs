//! The declarative campaign engine: typed, planned-load operations the
//! paper describes running against the real system — end-of-year
//! **reprocessing** (bulk rule creation over an entire datatype),
//! **mass deletion** (lifetime-expiry sweeps feeding the §4.3 reapers),
//! and the **tape carousel** (staged recall waves through the tape
//! systems, paced by throttler activity shares and per-link FTS caps).
//!
//! A [`CampaignSpec`] names the operation; [`run_campaign`] executes it
//! on a fully-wired [`Driver`] under virtual time via
//! [`Driver::run_span`], sampling the backlog/lock/deletion/recall
//! curves as it goes, and condenses the run into a
//! [`CampaignReport`]. Campaigns use only the virtual clock and the
//! catalog's own bulk APIs, so a fixed-seed run is bit-for-bit
//! reproducible — the standing test suite compares whole reports.

use std::collections::BTreeMap;

use crate::analytics::campaigns::{CampaignReport, CampaignSample};
use crate::analytics::chaos::BacklogSample;
use crate::common::clock::{EpochMs, HOUR_MS, MINUTE_MS};
use crate::common::error::Result;
use crate::core::metaexpr;
use crate::core::rules_api::RuleSpec;
use crate::core::types::{DidKey, RuleState};
use crate::daemons::Ctx;
use crate::sim::driver::Driver;

/// What a campaign does. Every variant selects its victim datasets with
/// a metadata expression (e.g. `datatype=RAW&project=data18`) evaluated
/// through the catalog's meta-expression index.
#[derive(Debug, Clone)]
pub enum CampaignKind {
    /// Bulk rule creation over every matching dataset: one rule per
    /// dataset on `destination`, injected through `add_rules_bulk` in
    /// batches of `batch`. The campaign completes when every created
    /// rule reaches `Ok`.
    Reprocessing {
        scope: String,
        filter: String,
        destination: String,
        copies: u32,
        lifetime_ms: Option<i64>,
        batch: usize,
    },
    /// Lifetime-expiry sweep: every rule protecting a matching dataset
    /// is expired in bulk; the judge removes the rules, tombstones flow
    /// to the reapers (greedy and non-greedy alike), and the campaign
    /// completes when the expired rules are gone and the replica
    /// population of the targeted data has converged — zero everywhere,
    /// or stable where non-greedy caches legitimately keep it.
    MassDeletion { scope: String, filter: String },
    /// Staged recall waves: matching tape-resident datasets are
    /// processed `wave_datasets` at a time — each wave pre-stages its
    /// files on the tape systems (batched through the staging queue)
    /// and pins them to `destination` with short-lived rules. A wave
    /// must fully land before the next starts, so the stage-in flood is
    /// paced by the throttler's activity shares and never outruns the
    /// per-link FTS caps.
    TapeCarousel {
        scope: String,
        filter: String,
        destination: String,
        lifetime_ms: i64,
        wave_datasets: usize,
    },
}

impl CampaignKind {
    fn label(&self) -> &'static str {
        match self {
            CampaignKind::Reprocessing { .. } => "reprocessing",
            CampaignKind::MassDeletion { .. } => "mass-deletion",
            CampaignKind::TapeCarousel { .. } => "tape-carousel",
        }
    }
}

/// One declarative campaign: the operation plus its execution envelope.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub name: String,
    /// Account owning any rules the campaign creates.
    pub account: String,
    /// Activity for created rules' transfers (throttler share key).
    pub activity: String,
    pub kind: CampaignKind,
    /// Virtual-time budget; a campaign that has not converged when the
    /// budget runs out is reported with `completed = false`.
    pub budget_hours: i64,
    /// Simulation tick resolution while the campaign runs.
    pub tick_ms: i64,
    /// Curve-sampling cadence.
    pub sample_every_ms: i64,
}

impl CampaignSpec {
    fn envelope(name: &str, account: &str, activity: &str, kind: CampaignKind) -> Self {
        CampaignSpec {
            name: name.to_string(),
            account: account.to_string(),
            activity: activity.to_string(),
            kind,
            budget_hours: 7 * 24,
            tick_ms: 10 * MINUTE_MS,
            sample_every_ms: 30 * MINUTE_MS,
        }
    }

    /// Reprocessing campaign over `scope` datasets matching `filter`.
    pub fn reprocessing(name: &str, scope: &str, filter: &str, destination: &str) -> Self {
        Self::envelope(
            name,
            "prod",
            "Reprocessing",
            CampaignKind::Reprocessing {
                scope: scope.to_string(),
                filter: filter.to_string(),
                destination: destination.to_string(),
                copies: 1,
                lifetime_ms: None,
                batch: 100,
            },
        )
    }

    /// Mass-deletion campaign over `scope` datasets matching `filter`.
    pub fn mass_deletion(name: &str, scope: &str, filter: &str) -> Self {
        Self::envelope(
            name,
            "prod",
            "Production",
            CampaignKind::MassDeletion { scope: scope.to_string(), filter: filter.to_string() },
        )
    }

    /// Tape-carousel recall of `scope` datasets matching `filter`, in
    /// waves of `wave_datasets`, pinned to `destination` for 7 days.
    pub fn tape_carousel(
        name: &str,
        scope: &str,
        filter: &str,
        destination: &str,
        wave_datasets: usize,
    ) -> Self {
        Self::envelope(
            name,
            "prod",
            "Staging",
            CampaignKind::TapeCarousel {
                scope: scope.to_string(),
                filter: filter.to_string(),
                destination: destination.to_string(),
                lifetime_ms: 7 * 24 * HOUR_MS,
                wave_datasets: wave_datasets.max(1),
            },
        )
    }

    pub fn with_budget_hours(mut self, hours: i64) -> Self {
        self.budget_hours = hours.max(1);
        self
    }

    pub fn with_cadence(mut self, tick_ms: i64, sample_every_ms: i64) -> Self {
        self.tick_ms = tick_ms.max(MINUTE_MS);
        self.sample_every_ms = sample_every_ms.max(self.tick_ms);
        self
    }

    pub fn with_account(mut self, account: &str) -> Self {
        self.account = account.to_string();
        self
    }

    pub fn with_activity(mut self, activity: &str) -> Self {
        self.activity = activity.to_string();
        self
    }
}

/// Curve accumulator shared by every campaign kind: samples on the
/// driver's observe cadence, tracks per-link peaks against the FTS cap,
/// and baselines the reaper counters so deletion work is attributed to
/// the campaign window.
struct Curves {
    samples: Vec<CampaignSample>,
    per_link_peak: BTreeMap<(String, String), usize>,
    link_cap: usize,
    cap_exceeded: bool,
    start_deleted: u64,
    start_deleted_bytes: u64,
}

impl Curves {
    fn new(ctx: &Ctx) -> Curves {
        Curves {
            samples: Vec::new(),
            per_link_peak: BTreeMap::new(),
            link_cap: ctx.fts.iter().map(|f| f.max_active_per_link).max().unwrap_or(0),
            cap_exceeded: false,
            start_deleted: ctx.catalog.metrics.counter("reaper.deleted"),
            start_deleted_bytes: ctx.catalog.metrics.counter("reaper.deleted_bytes"),
        }
    }

    fn observe(&mut self, ctx: &Ctx, rules_pending: usize) {
        let cat = &ctx.catalog;
        let mut peak_link_active = 0;
        for fts in &ctx.fts {
            for (link, n) in fts.active_per_link() {
                peak_link_active = peak_link_active.max(n);
                if n > fts.max_active_per_link {
                    self.cap_exceeded = true;
                }
                let e = self.per_link_peak.entry(link).or_insert(0);
                *e = (*e).max(n);
            }
        }
        self.samples.push(CampaignSample {
            t: cat.now(),
            backlog: BacklogSample::capture(ctx),
            locks_total: cat.locks.len(),
            rules_pending,
            deleted_files: cat.metrics.counter("reaper.deleted") - self.start_deleted,
            deleted_bytes: cat.metrics.counter("reaper.deleted_bytes") - self.start_deleted_bytes,
            staging_depth: ctx.fleet.staging_depth(),
            peak_link_active,
        });
    }

    /// Fold the curves into a report skeleton.
    fn into_report(self, spec: &CampaignSpec, started_at: EpochMs, ctx: &Ctx) -> CampaignReport {
        let peak_backlog = self.samples.iter().map(|s| s.backlog.backlog()).max().unwrap_or(0);
        let peak_locks = self.samples.iter().map(|s| s.locks_total).max().unwrap_or(0);
        let max_wave_depth = self.samples.iter().map(|s| s.staging_depth).max().unwrap_or(0);
        let finished_at = ctx.catalog.now();
        let deleted_files = ctx.catalog.metrics.counter("reaper.deleted") - self.start_deleted;
        let deleted_bytes =
            ctx.catalog.metrics.counter("reaper.deleted_bytes") - self.start_deleted_bytes;
        let hours = ((finished_at - started_at) as f64 / HOUR_MS as f64).max(1e-9);
        CampaignReport {
            name: spec.name.clone(),
            kind: spec.kind.label().to_string(),
            started_at,
            finished_at,
            deleted_files,
            deleted_bytes,
            deletion_rate_per_hour: deleted_files as f64 / hours,
            peak_backlog,
            peak_locks,
            max_wave_depth,
            per_link_peak: self.per_link_peak,
            link_cap: self.link_cap,
            link_cap_exceeded: self.cap_exceeded,
            samples: self.samples,
            ..Default::default()
        }
    }
}

/// Campaign rules not yet converged: `Ok` and *vanished* rules (judged
/// away, expired) both count as settled.
fn pending_rules(ctx: &Ctx, rule_ids: &[u64]) -> usize {
    rule_ids
        .iter()
        .filter(|id| ctx.catalog.rules.get(id).map(|r| r.state != RuleState::Ok).unwrap_or(false))
        .count()
}

/// Rules still present in the catalog (mass-deletion convergence).
fn surviving_rules(ctx: &Ctx, rule_ids: &[u64]) -> usize {
    rule_ids.iter().filter(|id| ctx.catalog.rules.get(id).is_some()).count()
}

/// Datasets in `scope` matching `filter` (collections only — campaign
/// granularity is the dataset, as in the paper's operational workflows).
fn select_datasets(ctx: &Ctx, scope: &str, filter: &str) -> Result<Vec<DidKey>> {
    let expr = metaexpr::parse(filter)?;
    Ok(ctx
        .catalog
        .query_dids(scope, &expr, false)
        .into_iter()
        .filter(|d| d.did_type.is_collection())
        .map(|d| d.key)
        .collect())
}

/// Execute one campaign on the driver. The driver's background workload,
/// daemon fleet, and (when enabled) invariant checking keep running —
/// campaigns are planned load *on top of* normal traffic, not a bench
/// harness in a vacuum.
pub fn run_campaign(driver: &mut Driver, spec: &CampaignSpec) -> Result<CampaignReport> {
    match spec.kind.clone() {
        CampaignKind::Reprocessing { scope, filter, destination, copies, lifetime_ms, batch } => {
            run_reprocessing(
                driver,
                spec,
                &scope,
                &filter,
                &destination,
                copies,
                lifetime_ms,
                batch,
            )
        }
        CampaignKind::MassDeletion { scope, filter } => {
            run_mass_deletion(driver, spec, &scope, &filter)
        }
        CampaignKind::TapeCarousel { scope, filter, destination, lifetime_ms, wave_datasets } => {
            run_tape_carousel(
                driver,
                spec,
                &scope,
                &filter,
                &destination,
                lifetime_ms,
                wave_datasets,
            )
        }
    }
}

/// Run a sequence of campaigns back to back (a "season"), returning one
/// report per campaign.
pub fn run_season(driver: &mut Driver, specs: &[CampaignSpec]) -> Result<Vec<CampaignReport>> {
    let mut reports = Vec::with_capacity(specs.len());
    for spec in specs {
        reports.push(run_campaign(driver, spec)?);
    }
    Ok(reports)
}

/// Drive chunk size: coarse enough to amortize completion checks, fine
/// enough that `time_to_complete` is meaningful.
fn chunk_ms(spec: &CampaignSpec) -> i64 {
    HOUR_MS.max(spec.tick_ms)
}

#[allow(clippy::too_many_arguments)]
fn run_reprocessing(
    driver: &mut Driver,
    spec: &CampaignSpec,
    scope: &str,
    filter: &str,
    destination: &str,
    copies: u32,
    lifetime_ms: Option<i64>,
    batch: usize,
) -> Result<CampaignReport> {
    let started_at = driver.ctx.catalog.now();
    let deadline = started_at + spec.budget_hours * HOUR_MS;
    let datasets = select_datasets(&driver.ctx, scope, filter)?;
    let mut curves = Curves::new(&driver.ctx);

    // Inject the rules in bulk batches. A failed batch rolls back atomically
    // inside `add_rules_bulk`; the campaign records it and carries on.
    let mut rule_ids: Vec<u64> = Vec::with_capacity(datasets.len());
    let mut batches_failed = 0;
    for chunk in datasets.chunks(batch.max(1)) {
        let specs: Vec<RuleSpec> = chunk
            .iter()
            .map(|key| {
                let mut rs = RuleSpec::new(&spec.account, key.clone(), destination, copies)
                    .with_activity(&spec.activity);
                if let Some(ms) = lifetime_ms {
                    rs = rs.with_lifetime(ms);
                }
                rs
            })
            .collect();
        match driver.ctx.catalog.add_rules_bulk(specs) {
            Ok(ids) => rule_ids.extend(ids),
            Err(_) => batches_failed += 1,
        }
    }
    let locks_created: usize =
        rule_ids.iter().map(|id| driver.ctx.catalog.locks_by_rule.count(id)).sum();

    // Drive the stack until every campaign rule settles (or budget ends).
    let mut completed_at = None;
    while driver.ctx.catalog.now() < deadline {
        if pending_rules(&driver.ctx, &rule_ids) == 0 {
            completed_at = Some(driver.ctx.catalog.now());
            break;
        }
        driver.run_span(chunk_ms(spec), spec.tick_ms, spec.sample_every_ms, |ctx| {
            let pending = pending_rules(ctx, &rule_ids);
            curves.observe(ctx, pending);
        });
    }
    if completed_at.is_none() && pending_rules(&driver.ctx, &rule_ids) == 0 {
        completed_at = Some(driver.ctx.catalog.now());
    }
    curves.observe(&driver.ctx, pending_rules(&driver.ctx, &rule_ids));

    let mut report = curves.into_report(spec, started_at, &driver.ctx);
    report.datasets_targeted = datasets.len();
    report.rules_created = rule_ids.len();
    report.batches_failed = batches_failed;
    report.locks_created = locks_created;
    report.completed = completed_at.is_some();
    report.time_to_complete_ms = completed_at.map(|t| t - started_at);
    Ok(report)
}

fn run_mass_deletion(
    driver: &mut Driver,
    spec: &CampaignSpec,
    scope: &str,
    filter: &str,
) -> Result<CampaignReport> {
    let started_at = driver.ctx.catalog.now();
    let deadline = started_at + spec.budget_hours * HOUR_MS;
    let datasets = select_datasets(&driver.ctx, scope, filter)?;
    let mut curves = Curves::new(&driver.ctx);
    let cat = driver.ctx.catalog.clone();

    // Every rule protecting the targeted datasets expires *now*; the
    // judge processes the expiries, tombstones land, reapers sweep.
    let mut rule_ids: Vec<u64> = Vec::new();
    for key in &datasets {
        for rule in cat.list_rules_for_did(key) {
            rule_ids.push(rule.id);
        }
    }
    rule_ids.sort_unstable();
    rule_ids.dedup();
    let rules_expired = cat.set_rule_expiration_bulk(&rule_ids, Some(started_at - 1));

    // Replicas of the targeted files: convergence means zero left, or an
    // unchanged population once deletion *can* have happened (after the
    // tombstone grace) — non-greedy reapers legitimately cache the rest.
    let target_files = |ctx: &Ctx| -> usize {
        datasets
            .iter()
            .flat_map(|d| ctx.catalog.list_content(d, false))
            .map(|f| ctx.catalog.list_replicas(&f.key).len())
            .sum()
    };
    let grace_ms = cat.cfg.get_duration_ms("reaper", "tombstone_grace", 24 * HOUR_MS);

    let mut completed_at = None;
    let mut prev_remaining = usize::MAX;
    while driver.ctx.catalog.now() < deadline {
        driver.run_span(chunk_ms(spec), spec.tick_ms, spec.sample_every_ms, |ctx| {
            let pending = surviving_rules(ctx, &rule_ids);
            curves.observe(ctx, pending);
        });
        if surviving_rules(&driver.ctx, &rule_ids) > 0 {
            continue;
        }
        let remaining = target_files(&driver.ctx);
        let grace_over = driver.ctx.catalog.now() >= started_at + grace_ms;
        if remaining == 0 || (grace_over && remaining == prev_remaining) {
            completed_at = Some(driver.ctx.catalog.now());
            break;
        }
        prev_remaining = remaining;
    }
    curves.observe(&driver.ctx, surviving_rules(&driver.ctx, &rule_ids));

    let mut report = curves.into_report(spec, started_at, &driver.ctx);
    report.datasets_targeted = datasets.len();
    report.rules_expired = rules_expired;
    report.completed = completed_at.is_some();
    report.time_to_complete_ms = completed_at.map(|t| t - started_at);
    Ok(report)
}

fn run_tape_carousel(
    driver: &mut Driver,
    spec: &CampaignSpec,
    scope: &str,
    filter: &str,
    destination: &str,
    lifetime_ms: i64,
    wave_datasets: usize,
) -> Result<CampaignReport> {
    let started_at = driver.ctx.catalog.now();
    let deadline = started_at + spec.budget_hours * HOUR_MS;
    let cat = driver.ctx.catalog.clone();
    let mut curves = Curves::new(&driver.ctx);

    // Tape-resident matching datasets, with their per-tape-RSE file PFNs
    // (the stage-in work of each wave).
    let mut carousel: Vec<(DidKey, BTreeMap<String, Vec<String>>)> = Vec::new();
    for key in select_datasets(&driver.ctx, scope, filter)? {
        let mut tape_pfns: BTreeMap<String, Vec<String>> = BTreeMap::new();
        for file in cat.list_content(&key, false) {
            for rep in cat.list_replicas(&file.key) {
                let on_tape = cat.get_rse(&rep.rse).map(|r| r.is_tape).unwrap_or(false);
                if on_tape {
                    tape_pfns.entry(rep.rse.clone()).or_default().push(rep.pfn.clone());
                }
            }
        }
        if !tape_pfns.is_empty() {
            carousel.push((key, tape_pfns));
        }
    }

    let mut rules_created = 0;
    let mut locks_created = 0;
    let mut batches_failed = 0;
    let mut waves = 0;
    let mut all_landed = true;
    'waves: for wave in carousel.chunks(wave_datasets) {
        waves += 1;
        let now = driver.ctx.catalog.now();
        // Pre-stage the wave's files: one batched recall per tape system,
        // so the robot queue (and its 30s-per-file contention) is shared
        // by the whole wave, exactly like a real carousel slot.
        for (_, tape_pfns) in wave {
            for (rse, pfns) in tape_pfns {
                if let Some(sys) = driver.ctx.fleet.get(rse) {
                    sys.stage_batch(pfns, now);
                }
            }
        }
        // Pin the wave to disk with short-lived Staging rules.
        let specs: Vec<RuleSpec> = wave
            .iter()
            .map(|(key, _)| {
                RuleSpec::new(&spec.account, key.clone(), destination, 1)
                    .with_activity(&spec.activity)
                    .with_lifetime(lifetime_ms)
            })
            .collect();
        let wave_rules = match cat.add_rules_bulk(specs) {
            Ok(ids) => ids,
            Err(_) => {
                batches_failed += 1;
                continue;
            }
        };
        locks_created += wave_rules.iter().map(|id| cat.locks_by_rule.count(id)).sum::<usize>();
        rules_created += wave_rules.len();

        // The next wave starts only when this one has fully landed.
        loop {
            if pending_rules(&driver.ctx, &wave_rules) == 0 {
                break;
            }
            if driver.ctx.catalog.now() >= deadline {
                all_landed = false;
                break 'waves;
            }
            driver.run_span(chunk_ms(spec), spec.tick_ms, spec.sample_every_ms, |ctx| {
                let pending = pending_rules(ctx, &wave_rules);
                curves.observe(ctx, pending);
            });
        }
    }
    curves.observe(&driver.ctx, 0);

    let mut report = curves.into_report(spec, started_at, &driver.ctx);
    report.datasets_targeted = carousel.len();
    report.rules_created = rules_created;
    report.locks_created = locks_created;
    report.batches_failed = batches_failed;
    report.waves = waves;
    report.completed = all_landed;
    report.time_to_complete_ms = all_landed.then(|| driver.ctx.catalog.now() - started_at);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_builders_fill_envelopes() {
        let r = CampaignSpec::reprocessing("r", "data18", "datatype=RAW", "tier=1&type=disk")
            .with_budget_hours(12)
            .with_cadence(MINUTE_MS, 5 * MINUTE_MS)
            .with_account("tzero")
            .with_activity("Data Rebalancing");
        assert_eq!(r.budget_hours, 12);
        assert_eq!(r.tick_ms, MINUTE_MS);
        assert_eq!(r.sample_every_ms, 5 * MINUTE_MS);
        assert_eq!(r.account, "tzero");
        assert_eq!(r.activity, "Data Rebalancing");
        assert_eq!(r.kind.label(), "reprocessing");

        let d = CampaignSpec::mass_deletion("d", "mc20", "datatype=AOD");
        assert_eq!(d.kind.label(), "mass-deletion");
        assert_eq!(d.budget_hours, 7 * 24, "default week budget");

        let c = CampaignSpec::tape_carousel("c", "data18", "datatype=RAW", "tier=1&type=disk", 0);
        match c.kind {
            CampaignKind::TapeCarousel { wave_datasets, .. } => {
                assert_eq!(wave_datasets, 1, "wave size clamped to >= 1")
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn cadence_clamps_sampling_to_tick() {
        let s = CampaignSpec::mass_deletion("d", "mc20", "datatype=AOD")
            .with_cadence(10 * MINUTE_MS, MINUTE_MS);
        assert_eq!(s.sample_every_ms, 10 * MINUTE_MS, "cannot sample finer than the tick");
    }
}
