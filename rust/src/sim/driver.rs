//! The discrete-event simulation driver: runs the complete stack —
//! workload, daemons, FTS, storage, network — under virtual time and
//! collects the series behind every paper figure.
//!
//! Chaos support: a scheduled [`Scenario`] timeline is applied at the
//! right virtual instants (including daemon crash/restart, which the
//! driver owns), the [`crate::sim::invariants`] checker runs every N
//! virtual minutes, and [`BacklogSample`]s are captured for the
//! per-scenario recovery report.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::analytics::chaos::{recovery_report, BacklogSample, RecoveryReport};
use crate::common::clock::{Clock, DAY_MS, EpochMs, HOUR_MS, MINUTE_MS};
use crate::daemons::{Ctx, Daemon};
use crate::mq::SubId;
use crate::sim::grid::region_of;
use crate::sim::invariants::{self, Violation};
use crate::sim::scenario::{Event, Scenario};
use crate::sim::workload::Workload;

/// Per-day aggregates (the figure sources). `PartialEq` so fixed-seed
/// determinism can be asserted by comparing whole runs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DayStats {
    pub day: u32,
    /// Fig 10: total catalog volume at end of day.
    pub bytes_managed: u64,
    pub files: u64,
    pub datasets: u64,
    pub containers: u64,
    pub replicas: u64,
    /// Fig 11: bytes transferred this day (successful).
    pub bytes_transferred: u64,
    pub transfers_done: u64,
    pub transfers_failed: u64,
    /// Fig 11 per-region destination split.
    pub bytes_by_dst_region: BTreeMap<String, u64>,
    /// Fig 8: per (src_region, dst_region) → (done, failed).
    pub pair_outcomes: BTreeMap<(String, String), (u64, u64)>,
    /// Fig 6: FTS submissions by activity this day.
    pub submissions_by_activity: BTreeMap<String, u64>,
    /// Deletion workload (§5.3): files + bytes deleted this day.
    pub deletions: u64,
    pub deleted_bytes: u64,
    pub deletion_errors: u64,
    /// tape recall
    pub tape_recall_bytes: u64,
    pub tape_recalls: u64,
}

/// One daemon instance owned by the driver. `crashed` instances stop
/// ticking (and therefore stop heartbeating — the hash ring rebalances
/// around them, §3.4) until restarted.
struct DaemonSlot {
    daemon: Box<dyn Daemon>,
    due: EpochMs,
    crashed: bool,
}

/// The driver owns the daemon fleet with per-daemon due times.
pub struct Driver {
    pub ctx: Ctx,
    pub workload: Workload,
    daemons: Vec<DaemonSlot>,
    fts_events: SubId,
    pub days: Vec<DayStats>,
    start: EpochMs,
    prev_activity_counts: BTreeMap<String, u64>,
    prev_deleted: u64,
    prev_deleted_bytes: u64,
    prev_del_errors: u64,
    /// Scheduled chaos events in absolute virtual time, sorted ascending.
    pending_events: Vec<(EpochMs, Event)>,
    next_event: usize,
    /// Invariant-check cadence (virtual ms); `None` = checking disabled.
    invariant_every_ms: Option<i64>,
    next_check: EpochMs,
    /// Every invariant violation observed, with the virtual time it was
    /// seen. Chaos tests assert this stays empty.
    pub violations: Vec<(EpochMs, Violation)>,
    /// Backlog series captured at every invariant cycle (recovery report
    /// input).
    pub samples: Vec<BacklogSample>,
    /// Next housekeeping tick (token purge + heartbeat expiry), hourly.
    next_housekeep: EpochMs,
    /// How many `ProcessCrash` events were applied (catalog dropped and
    /// recovered from WAL + snapshots mid-run).
    pub process_crashes: usize,
}

impl Driver {
    pub fn new(ctx: Ctx, workload: Workload, daemons: Vec<Box<dyn Daemon>>) -> Self {
        let start = ctx.catalog.now();
        let fts_events = ctx.broker.subscribe("transfer.fts", None);
        Driver {
            workload,
            daemons: daemons
                .into_iter()
                .map(|d| DaemonSlot { daemon: d, due: start, crashed: false })
                .collect(),
            fts_events,
            days: Vec::new(),
            start,
            prev_activity_counts: BTreeMap::new(),
            prev_deleted: 0,
            prev_deleted_bytes: 0,
            prev_del_errors: 0,
            pending_events: Vec::new(),
            next_event: 0,
            invariant_every_ms: None,
            next_check: start,
            violations: Vec::new(),
            samples: Vec::new(),
            next_housekeep: start,
            process_crashes: 0,
            ctx,
        }
    }

    // ------------------------------------------------------------------
    // chaos: scenario scheduling, daemon crash/restart, invariant checks
    // ------------------------------------------------------------------

    /// Schedule a scenario: its offsets become absolute virtual times
    /// from "now". Multiple scenarios may be scheduled; events merge.
    pub fn schedule_scenario(&mut self, scenario: &Scenario) {
        let base = self.ctx.catalog.now();
        // Drop already-applied events before re-sorting so they cannot
        // fire twice when scenarios are scheduled mid-run.
        self.pending_events.drain(..self.next_event);
        self.next_event = 0;
        for (offset, event) in &scenario.events {
            self.pending_events.push((base + offset, event.clone()));
        }
        self.pending_events.sort_by_key(|(t, _)| *t);
    }

    /// Run the invariant checker (and capture a backlog sample) every
    /// `every_ms` of virtual time. Violations accumulate in
    /// [`Driver::violations`].
    pub fn enable_invariant_checks(&mut self, every_ms: i64) {
        self.invariant_every_ms = Some(every_ms.max(MINUTE_MS));
        self.next_check = self.ctx.catalog.now();
    }

    /// Add another daemon instance to the fleet (e.g. a second conveyor
    /// submitter for failover scenarios). It starts ticking immediately.
    pub fn add_daemon(&mut self, daemon: Box<dyn Daemon>) {
        let now = self.ctx.catalog.now();
        self.daemons.push(DaemonSlot { daemon, due: now, crashed: false });
    }

    /// Crash the `which`-th instance (in fleet order) whose
    /// [`Daemon::name`] equals `name`. Returns false when no such
    /// instance exists.
    pub fn crash_daemon(&mut self, name: &str, which: usize) -> bool {
        let mut seen = 0;
        for slot in &mut self.daemons {
            if slot.daemon.name() == name {
                if seen == which {
                    slot.crashed = true;
                    return true;
                }
                seen += 1;
            }
        }
        false
    }

    /// Restart a crashed instance; it resumes ticking immediately.
    pub fn restart_daemon(&mut self, name: &str, which: usize) -> bool {
        let now = self.ctx.catalog.now();
        let mut seen = 0;
        for slot in &mut self.daemons {
            if slot.daemon.name() == name {
                if seen == which {
                    slot.crashed = false;
                    slot.due = now;
                    return true;
                }
                seen += 1;
            }
        }
        false
    }

    fn apply_due_events(&mut self, now: EpochMs) {
        while self.next_event < self.pending_events.len()
            && self.pending_events[self.next_event].0 <= now
        {
            let (_, event) = self.pending_events[self.next_event].clone();
            self.next_event += 1;
            match &event {
                Event::DaemonCrash { daemon, which } => {
                    self.crash_daemon(daemon, *which);
                }
                Event::DaemonRestart { daemon, which } => {
                    self.restart_daemon(daemon, *which);
                }
                Event::ProcessCrash => {
                    self.process_crash_and_recover();
                }
                other => crate::sim::scenario::apply(&self.ctx, other, now),
            }
        }
    }

    /// Apply a whole-process crash to the catalog: drop the live
    /// in-memory state, cold-boot a replacement from the durability
    /// directory ([`crate::core::Catalog::open_with`], same virtual
    /// clock and config), restart the standard daemon fleet against the
    /// recovered catalog, and immediately run the full invariant suite.
    /// Infrastructure outside the catalog process (storage, network,
    /// FTS, broker, heartbeats) survives, exactly like a real server
    /// crash. Returns false (with a warning) when durability is off or
    /// recovery fails; a failure is also recorded as a violation so
    /// chaos tests cannot miss it.
    pub fn process_crash_and_recover(&mut self) -> bool {
        if !self.ctx.catalog.durable() {
            crate::log_warn!("ProcessCrash ignored: [db] wal_dir not configured");
            return false;
        }
        let cfg = self.ctx.catalog.cfg.clone();
        let clock = self.ctx.catalog.clock.clone(); // shared SimClock: virtual time continues
        match crate::core::Catalog::open_with(clock, cfg) {
            Ok(recovered) => {
                self.ctx.catalog = Arc::new(recovered);
                let now = self.ctx.catalog.now();
                // The daemon fleet held handles to the dead catalog —
                // restart it, like daemons coming back after a host reboot.
                self.daemons = Driver::standard_daemons(&self.ctx)
                    .into_iter()
                    .map(|d| DaemonSlot { daemon: d, due: now, crashed: false })
                    .collect();
                // Catalog metrics restarted from zero: reset the
                // day-delta baselines derived from them.
                self.prev_deleted = 0;
                self.prev_deleted_bytes = 0;
                self.prev_del_errors = 0;
                self.process_crashes += 1;
                self.check_invariants_now();
                true
            }
            Err(e) => {
                self.violations.push((
                    self.ctx.catalog.now(),
                    Violation {
                        invariant: "process-crash-recovery",
                        detail: e.to_string(),
                    },
                ));
                false
            }
        }
    }

    /// Run the invariant checker + backlog sampling right now (the
    /// end-of-run check; also called on the configured cadence).
    pub fn check_invariants_now(&mut self) {
        let now = self.ctx.catalog.now();
        self.samples.push(BacklogSample::capture(&self.ctx));
        for v in invariants::check(&self.ctx.catalog) {
            self.violations.push((now, v));
        }
        // deployment-level: per-link FTS concurrency caps hold throughout
        for v in invariants::check_fts_link_caps(&self.ctx) {
            self.violations.push((now, v));
        }
    }

    /// Recovery report over the captured backlog series for a fault
    /// window (virtual timestamps, as absolute times).
    pub fn recovery_report(&self, fault_start: EpochMs, fault_cleared: EpochMs) -> RecoveryReport {
        recovery_report(&self.samples, fault_start, fault_cleared)
    }

    /// The standard daemon fleet (one instance of each core daemon).
    pub fn standard_daemons(ctx: &Ctx) -> Vec<Box<dyn Daemon>> {
        use crate::daemons::*;
        vec![
            Box::new(checkpointer::Checkpointer::new(ctx.clone())),
            Box::new(hermes::Hermes::new(ctx.clone())),
            Box::new(transmogrifier::Transmogrifier::new(ctx.clone(), "trans-1")),
            Box::new(throttler::Throttler::new(ctx.clone(), "throt-1")),
            Box::new(conveyor::Submitter::new(ctx.clone(), "sub-1")),
            Box::new(conveyor::Receiver::new(ctx.clone())),
            Box::new(conveyor::Poller::new(ctx.clone(), "poll-1")),
            Box::new(judge::Cleaner::new(ctx.clone(), "clean-1")),
            Box::new(judge::Repairer::new(ctx.clone(), "rep-1")),
            Box::new(judge::Undertaker::new(ctx.clone(), "und-1")),
            Box::new(reaper::Reaper::new(ctx.clone(), "reap-1")),
            Box::new(tracer::Tracer::new(ctx.clone())),
            Box::new(tracer::DistanceUpdater { ctx: ctx.clone() }),
            Box::new(necromancer::Necromancer::new(ctx.clone(), "necro-1")),
            Box::new(auditor::Auditor::new(ctx.clone(), "aud-1")),
            Box::new(c3po::HeatC3po::new(ctx.clone())),
            Box::new(bb8::Bb8Daemon::new(ctx.clone())),
        ]
    }

    fn sim_clock(&self) -> &crate::common::clock::SimClock {
        match &self.ctx.catalog.clock {
            Clock::Sim(s) => s,
            _ => panic!("driver requires a simulated clock"),
        }
    }

    /// Run `days` simulated days with `tick_ms` resolution. When
    /// invariant checking is enabled, a final end-of-run check always
    /// executes.
    pub fn run_days(&mut self, days: u32, tick_ms: i64) {
        for _ in 0..days {
            self.run_one_day(tick_ms.max(MINUTE_MS));
        }
        if self.invariant_every_ms.is_some() {
            self.check_invariants_now();
        }
    }

    fn run_one_day(&mut self, tick_ms: i64) {
        let day = self.days.len() as u32;
        let mut stats = DayStats { day, ..Default::default() };
        let day_end = self.ctx.catalog.now() + DAY_MS;

        while self.ctx.catalog.now() < day_end {
            self.step_once(tick_ms, day, &mut stats);
        }

        // periodic tape recall campaign (every 5th day)
        if day % 5 == 4 {
            self.workload.recall_campaign(&self.ctx, self.ctx.catalog.now());
        }

        self.finish_day(&mut stats);
        self.days.push(stats);
    }

    /// One simulation tick: chaos events, workload, daemons, housekeeping,
    /// infrastructure, event harvest, invariant cadence, clock advance.
    /// Shared by the daily loop and [`Driver::run_span`].
    fn step_once(&mut self, tick_ms: i64, day: u32, stats: &mut DayStats) {
        let now = self.ctx.catalog.now();
        // 0. due chaos events fire first (faults hit a consistent
        //    catalog, exactly like a real incident between requests)
        self.apply_due_events(now);
        // 1. workload generates activity
        self.workload.step(&self.ctx, now, tick_ms, day);
        // 2. due daemons tick (crashed instances stay silent)
        for slot in self.daemons.iter_mut() {
            if !slot.crashed && now >= slot.due {
                slot.daemon.tick(now);
                slot.due = now + slot.daemon.interval_ms();
            }
        }
        // 2b. hourly housekeeping: expired auth tokens leave the
        //     catalog, fully-silent heartbeat entries are pruned
        if now >= self.next_housekeep {
            let purged = self.ctx.catalog.purge_expired_tokens();
            if purged > 0 {
                self.ctx
                    .catalog
                    .metrics
                    .incr("housekeeping.tokens_purged", purged as u64);
            }
            self.ctx.heartbeats.expire_dead(now);
            self.next_housekeep = now + HOUR_MS;
        }
        // 3. infrastructure advances
        for fts in &self.ctx.fts {
            fts.advance(now);
        }
        self.ctx.fleet.tick(now);
        // 4. harvest FTS events for figure accounting
        self.harvest_fts_events(stats);
        // 5. system invariants hold at every quiescent point
        if let Some(every) = self.invariant_every_ms {
            if now >= self.next_check {
                self.check_invariants_now();
                self.next_check = now + every;
            }
        }
        // 6. virtual time moves
        self.sim_clock().advance(tick_ms);
    }

    /// Campaign hook: run the full stack for an arbitrary virtual span —
    /// not day-aligned — invoking `observe(&ctx)` every `observe_every_ms`
    /// so a campaign runner can sample its backlog/lock/deletion curves
    /// between daemon ticks. Invariant checking (when enabled) and the
    /// background workload keep running exactly as in [`Driver::run_days`];
    /// the span's transfer/deletion aggregates are returned as a
    /// [`DayStats`] (its `day` field is the current day index) without
    /// being pushed onto [`Driver::days`].
    pub fn run_span<F: FnMut(&Ctx)>(
        &mut self,
        duration_ms: i64,
        tick_ms: i64,
        observe_every_ms: i64,
        mut observe: F,
    ) -> DayStats {
        let day = self.days.len() as u32;
        let mut stats = DayStats { day, ..Default::default() };
        let tick_ms = tick_ms.max(MINUTE_MS);
        let end = self.ctx.catalog.now() + duration_ms;
        let mut next_obs = self.ctx.catalog.now();
        while self.ctx.catalog.now() < end {
            self.step_once(tick_ms, day, &mut stats);
            if self.ctx.catalog.now() >= next_obs {
                observe(&self.ctx);
                next_obs = self.ctx.catalog.now() + observe_every_ms.max(tick_ms);
            }
        }
        self.finish_day(&mut stats);
        stats
    }

    fn harvest_fts_events(&mut self, stats: &mut DayStats) {
        let cat = &self.ctx.catalog;
        loop {
            let msgs = self.ctx.broker.poll("transfer.fts", self.fts_events, 2000);
            if msgs.is_empty() {
                break;
            }
            for m in msgs {
                let src = m.payload.opt_str("src_rse").unwrap_or("?");
                let dst = m.payload.opt_str("dst_rse").unwrap_or("?");
                let bytes = m.payload.opt_u64("bytes").unwrap_or(0);
                let src_region = region_of(cat, src);
                let dst_region = region_of(cat, dst);
                let pair = stats
                    .pair_outcomes
                    .entry((src_region, dst_region.clone()))
                    .or_insert((0, 0));
                match m.event_type.as_str() {
                    "transfer-done" => {
                        pair.0 += 1;
                        stats.transfers_done += 1;
                        stats.bytes_transferred += bytes;
                        *stats.bytes_by_dst_region.entry(dst_region).or_insert(0) += bytes;
                        let src_tape = cat.get_rse(src).map(|r| r.is_tape).unwrap_or(false);
                        if src_tape {
                            stats.tape_recalls += 1;
                            stats.tape_recall_bytes += bytes;
                        }
                    }
                    "transfer-failed" => {
                        pair.1 += 1;
                        stats.transfers_failed += 1;
                    }
                    _ => {}
                }
            }
        }
    }

    fn finish_day(&mut self, stats: &mut DayStats) {
        let cat = &self.ctx.catalog;
        let ns = cat.namespace_stats();
        stats.bytes_managed = ns.bytes_managed;
        stats.files = ns.files;
        stats.datasets = ns.datasets;
        stats.containers = ns.containers;
        stats.replicas = ns.replicas;

        // Fig 6: per-activity FTS submissions (delta of cumulative totals)
        let mut current: BTreeMap<String, u64> = BTreeMap::new();
        for fts in &self.ctx.fts {
            for (act, n) in fts.submitted_by_activity() {
                *current.entry(act).or_insert(0) += n;
            }
        }
        for (act, n) in &current {
            let prev = self.prev_activity_counts.get(act).copied().unwrap_or(0);
            stats.submissions_by_activity.insert(act.clone(), n - prev);
        }
        self.prev_activity_counts = current;

        // deletion deltas from the reaper's counters
        let deleted = cat.metrics.counter("reaper.deleted");
        let deleted_bytes = cat.metrics.counter("reaper.deleted_bytes");
        let errors = cat.metrics.counter("reaper.errors");
        stats.deletions = deleted - self.prev_deleted;
        stats.deleted_bytes = deleted_bytes - self.prev_deleted_bytes;
        stats.deletion_errors = errors - self.prev_del_errors;
        self.prev_deleted = deleted;
        self.prev_deleted_bytes = deleted_bytes;
        self.prev_del_errors = errors;
    }

    /// Aggregate the Fig-8 efficiency matrix over all recorded days:
    /// (src_region, dst_region) → efficiency in [0, 1].
    pub fn efficiency_matrix(&self) -> BTreeMap<(String, String), f64> {
        let mut acc: BTreeMap<(String, String), (u64, u64)> = BTreeMap::new();
        for d in &self.days {
            for (pair, (ok, fail)) in &d.pair_outcomes {
                let e = acc.entry(pair.clone()).or_insert((0, 0));
                e.0 += ok;
                e.1 += fail;
            }
        }
        acc.into_iter()
            .filter(|(_, (ok, fail))| ok + fail > 0)
            .map(|(pair, (ok, fail))| (pair, ok as f64 / (ok + fail) as f64))
            .collect()
    }

    /// Total simulated elapsed time.
    pub fn elapsed_ms(&self) -> EpochMs {
        self.ctx.catalog.now() - self.start
    }
}

/// Convenience: build a fully-wired driver on the standard grid.
///
/// Seed threading: one explicit seed reproduces a whole run. `GridSpec::
/// seed` derives the per-endpoint storage fault streams and the FTS
/// quality rolls (see [`crate::sim::grid::build_grid`]); unless the
/// config already pins `[common] seed`, the same value also seeds the
/// catalog PRNG (rule placement). `WorkloadSpec::seed` drives the
/// workload generator. With those fixed, a run is bit-for-bit
/// deterministic — the chaos suite asserts identical per-day stats
/// across repeated runs.
pub fn standard_driver(
    grid: &crate::sim::grid::GridSpec,
    workload: crate::sim::workload::WorkloadSpec,
    mut cfg: crate::common::config::Config,
) -> Driver {
    if cfg.get("common", "seed").is_none() {
        cfg.set("common", "seed", grid.seed.to_string());
    }
    let ctx = crate::sim::grid::build_grid(grid, Clock::sim_at(1_514_764_800_000), cfg); // 2018-01-01
    let daemons = Driver::standard_daemons(&ctx);
    let _ = Arc::strong_count(&ctx.catalog);
    Driver::new(ctx.clone(), Workload::new(workload), daemons)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::grid::GridSpec;
    use crate::sim::workload::WorkloadSpec;

    fn small_driver() -> Driver {
        let mut cfg = crate::common::config::Config::new();
        // fast-reacting daemons for short sims
        cfg.set("reaper", "tombstone_grace", "1h");
        standard_driver(
            &GridSpec { t2_per_region: 1, ..Default::default() },
            WorkloadSpec {
                raw_datasets_per_day: 4,
                files_per_dataset: 4,
                median_file_bytes: 500_000_000,
                derivations_per_day: 3,
                analysis_accesses_per_day: 40,
                ..Default::default()
            },
            cfg,
        )
    }

    #[test]
    fn two_day_sim_produces_activity() {
        let mut driver = small_driver();
        driver.run_days(2, 10 * MINUTE_MS);
        assert_eq!(driver.days.len(), 2);
        let d1 = &driver.days[1];
        assert!(d1.bytes_managed > 0, "catalog grew");
        assert!(d1.files > 0);
        assert!(d1.transfers_done > 0, "subscriptions moved RAW data: {d1:?}");
        assert!(
            d1.submissions_by_activity.contains_key("T0 Export"),
            "{:?}",
            d1.submissions_by_activity
        );
        // volume grows monotonically across days (Fig 10 shape)
        assert!(driver.days[1].bytes_managed >= driver.days[0].bytes_managed / 2);
    }

    #[test]
    fn scenario_events_fire_and_invariants_hold() {
        let mut driver = small_driver();
        driver.enable_invariant_checks(6 * 60 * MINUTE_MS);
        let sc = Scenario::new("one-outage")
            .at_hours(2, Event::RseDown { rse: "CA-T2-1".into() })
            .at_hours(4, Event::DaemonCrash { daemon: "reaper".into(), which: 0 })
            .at_hours(8, Event::DaemonRestart { daemon: "reaper".into(), which: 0 })
            .at_hours(20, Event::RseUp { rse: "CA-T2-1".into() });
        driver.schedule_scenario(&sc);
        driver.run_days(1, 10 * MINUTE_MS);
        // all events consumed, outage ended, checker ran, nothing broke
        let rse = driver.ctx.catalog.get_rse("CA-T2-1").unwrap();
        assert!(rse.availability_write && rse.availability_read);
        assert!(!driver.ctx.fleet.get("CA-T2-1").unwrap().is_offline());
        assert!(driver.samples.len() >= 2, "sampled: {}", driver.samples.len());
        assert!(driver.violations.is_empty(), "{:?}", driver.violations);
    }

    #[test]
    fn crash_and_restart_target_the_right_instance() {
        let mut driver = small_driver();
        assert!(driver.crash_daemon("conveyor-submitter", 0));
        assert!(!driver.crash_daemon("conveyor-submitter", 1), "only one instance");
        assert!(!driver.crash_daemon("no-such-daemon", 0));
        assert!(driver.restart_daemon("conveyor-submitter", 0));
    }

    #[test]
    fn efficiency_matrix_populates() {
        let mut driver = small_driver();
        driver.run_days(2, 10 * MINUTE_MS);
        let m = driver.efficiency_matrix();
        assert!(!m.is_empty());
        for ((s, d), eff) in &m {
            assert!((0.0..=1.0).contains(eff), "{s}->{d}: {eff}");
        }
    }
}
