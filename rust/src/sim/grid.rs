//! Grid topology builder: the Fig-8 world — 12 regions (CA, CERN, DE, ES,
//! FR, IT, ND, NL, RU, TW, UK, US), Tier-0/1/2 sites with disk + tape,
//! LHCOPN/LHCONE-like links whose per-pair quality *causes* the paper's
//! efficiency-matrix structure, and RSE distances derived from bandwidth.

use std::sync::Arc;

use crate::common::clock::Clock;
use crate::common::config::Config;
use crate::common::units::{GB, TB};
use crate::core::rse::Rse;
use crate::core::subscriptions::{SubscriptionFilter, SubscriptionRule};
use crate::core::types::AccountType;
use crate::core::Catalog;
use crate::daemons::Ctx;
use crate::ftssim::FtsServer;
use crate::mq::Broker;
use crate::netsim::{Link, Network};
use crate::storagesim::{FailurePolicy, Fleet, StorageKind, StorageSystem};

/// The Fig-8 regions.
pub const REGIONS: [&str; 12] =
    ["CA", "CERN", "DE", "ES", "FR", "IT", "ND", "NL", "RU", "TW", "UK", "US"];

/// Per-region transfer reliability personalities — tuned so the simulated
/// efficiency matrix reproduces the paper's *structure* (strong CERN/CA/
/// ND/RU rows, weak DE→FR / ES / IT→US cells). These multiply pairwise.
fn region_reliability(region: &str) -> f64 {
    match region {
        "CERN" => 0.995,
        "CA" | "ND" | "RU" | "TW" => 0.98,
        "FR" | "NL" | "UK" => 0.96,
        "IT" => 0.93,
        "DE" => 0.91,
        "ES" | "US" => 0.90,
        _ => 0.95,
    }
}

/// Scale knobs for the simulated grid.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// Tier-2 disk RSEs per region (besides the T1 disk+tape).
    pub t2_per_region: usize,
    pub disk_capacity: u64,
    pub tape_capacity: u64,
    /// Storage-level failure injection (drives part of the error rates).
    pub storage_flakiness: f64,
    /// Number of redundant FTS servers (paper: CERN + US + UK).
    pub fts_servers: usize,
    pub seed: u64,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            t2_per_region: 2,
            disk_capacity: 50 * TB,
            tape_capacity: 400 * TB,
            storage_flakiness: 0.02,
            fts_servers: 3,
            seed: 42,
        }
    }
}

/// Build the full simulated deployment: catalog (with RSEs, accounts,
/// subscriptions), storage fleet, network, FTS servers, broker.
pub fn build_grid(spec: &GridSpec, clock: Clock, cfg: Config) -> Ctx {
    let catalog = Arc::new(Catalog::new(clock, cfg));
    let fleet = Arc::new(Fleet::new());
    let net = Arc::new(Network::new());
    let broker = Broker::new();
    let now = catalog.now();

    // ---- accounts + scopes
    for (acc, t) in [
        ("prod", AccountType::Service),
        ("tzero", AccountType::Service),
        ("alice", AccountType::User),
        ("bob", AccountType::User),
    ] {
        catalog.add_account(acc, t, &format!("{acc}@example.org")).unwrap();
    }
    catalog.set_admin("prod", true).unwrap();
    catalog.set_admin("tzero", true).unwrap();
    for scope in ["data18", "mc20"] {
        catalog.add_scope(scope, "prod").unwrap();
    }

    // ---- RSEs + storage
    let policy = FailurePolicy {
        write_fail: spec.storage_flakiness,
        read_fail: spec.storage_flakiness / 2.0,
        corrupt: spec.storage_flakiness / 20.0,
        delete_fail: spec.storage_flakiness * 2.0,
        ..Default::default()
    };
    let add_rse = |name: &str, region: &str, tier: &str, tape: bool, cap: u64| {
        let mut rse = Rse::new(name, now)
            .with_attr("region", region)
            .with_attr("country", region)
            .with_attr("tier", tier)
            .with_attr("site", name)
            .with_attr("type", if tape { "tape" } else { "disk" });
        if tape {
            rse = rse.with_tape();
        }
        catalog.add_rse(rse).unwrap();
        let kind = if tape { StorageKind::Tape } else { StorageKind::Disk };
        // Per-endpoint failure stream derived from the grid seed, so a
        // fixed GridSpec::seed reproduces the same fault sequence.
        fleet.add(
            StorageSystem::new(name, kind, cap)
                .with_policy(policy.clone())
                .with_seed(spec.seed ^ crate::db::shard_hash(name.as_bytes())),
        );
    };

    for region in REGIONS {
        if region == "CERN" {
            add_rse("CERN-PROD", region, "0", false, spec.disk_capacity * 4);
            add_rse("CERN-TAPE", region, "0", true, spec.tape_capacity * 2);
            continue;
        }
        add_rse(&format!("{region}-T1-DISK"), region, "1", false, spec.disk_capacity * 2);
        add_rse(&format!("{region}-T1-TAPE"), region, "1", true, spec.tape_capacity);
        for i in 1..=spec.t2_per_region {
            add_rse(&format!("{region}-T2-{i}"), region, "2", false, spec.disk_capacity);
        }
    }

    // ---- network: per-site links with region personalities
    let rses = catalog.list_rses();
    for a in &rses {
        for b in &rses {
            if a.name == b.name {
                continue;
            }
            let (ra, rb) = (
                a.attr("region").unwrap().to_string(),
                b.attr("region").unwrap().to_string(),
            );
            let quality = region_reliability(&ra) * region_reliability(&rb);
            let (bw, lat) = if ra == rb {
                (100 * GB / 8, 5) // intra-region
            } else if ra == "CERN" || rb == "CERN" {
                (100 * GB / 8, 15) // LHCOPN
            } else if a.attr("tier") == Some("1") && b.attr("tier") == Some("1") {
                (100 * GB / 8, 40) // T1 mesh over LHCONE
            } else {
                (40 * GB / 8, 60) // institute links
            };
            net.set_link(a.site(), b.site(), Link::new(bw, lat, quality));
        }
    }
    // seed distances from nominal bandwidth
    let mut samples: Vec<(String, String, f64)> = Vec::new();
    for a in &rses {
        for b in &rses {
            if a.name != b.name {
                let l = net.link(a.site(), b.site());
                samples.push((a.site().to_string(), b.site().to_string(), l.bandwidth_bps as f64));
            }
        }
    }
    catalog.update_distances_from_throughput(&samples);

    // ---- standing subscriptions (paper §2.5): RAW → tape + T1 disk
    catalog
        .add_subscription(
            "raw-tape-archival",
            "tzero",
            SubscriptionFilter {
                scopes: vec!["data18".into()],
                did_types: vec![],
                expr: Some(
                    crate::core::metaexpr::parse("datatype=RAW")
                        .expect("static subscription filter parses"),
                ),
            },
            vec![
                SubscriptionRule {
                    rse_expression: "tape".into(),
                    copies: 1,
                    lifetime_ms: None,
                    activity: "T0 Export".into(),
                },
                SubscriptionRule {
                    rse_expression: "tier=1&type=disk".into(),
                    copies: 1,
                    lifetime_ms: None,
                    activity: "T0 Export".into(),
                },
            ],
        )
        .unwrap();

    // ---- FTS servers
    let fts: Vec<Arc<FtsServer>> = (0..spec.fts_servers.max(1))
        .map(|i| {
            Arc::new(
                FtsServer::new(
                    &format!("fts{}", i + 1),
                    net.clone(),
                    fleet.clone(),
                    Some(broker.clone()),
                )
                .with_seed(spec.seed ^ (0xF75 + i as u64)),
            )
        })
        .collect();

    Ctx::new(catalog, fleet, net, fts, broker)
}

/// Region of an RSE (for the Fig-8/Fig-11 aggregations).
pub fn region_of(catalog: &Catalog, rse: &str) -> String {
    catalog
        .get_rse(rse)
        .ok()
        .and_then(|r| r.attr("region").map(|s| s.to_string()))
        .unwrap_or_else(|| "??".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_expected_shape() {
        let spec = GridSpec::default();
        let ctx = build_grid(&spec, Clock::sim_at(0), Config::new());
        let rses = ctx.catalog.list_rses();
        // CERN: 2; 11 other regions: 2 + t2_per_region each
        assert_eq!(rses.len(), 2 + 11 * (2 + spec.t2_per_region));
        assert!(ctx.fleet.get("CERN-PROD").is_some());
        assert!(ctx.fleet.get("DE-T1-TAPE").is_some());
        // expressions over the grid resolve
        let tapes = ctx.catalog.resolve_rse_expression("tape").unwrap();
        assert_eq!(tapes.len(), 12); // CERN + 11 T1 tapes
        let t2_fr = ctx.catalog.resolve_rse_expression("tier=2&region=FR").unwrap();
        assert_eq!(t2_fr.len(), spec.t2_per_region);
    }

    #[test]
    fn link_quality_reflects_personalities() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        let good = ctx.net.link("CERN-PROD", "CA-T1-DISK").quality;
        let bad = ctx.net.link("DE-T1-DISK", "ES-T1-DISK").quality;
        assert!(good > bad, "CERN→CA ({good}) should beat DE→ES ({bad})");
    }

    #[test]
    fn distances_seeded() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        assert!(ctx.catalog.distance("CERN-PROD", "FR-T1-DISK").is_some());
    }
}
