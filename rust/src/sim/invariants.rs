//! System-invariant checker: asserts the global consistency properties
//! the paper's bookkeeping implies (§2.5 locks/usage, §3.6 counters,
//! §4.2 requests) hold at any quiescent point between daemon ticks.
//!
//! The discrete-event driver runs this every N virtual minutes and at
//! end-of-run; chaos scenarios use it to prove that no fault sequence —
//! outages, partitions, corruption bursts, daemon crashes — can corrupt
//! the catalog, only delay its convergence.
//!
//! Invariant set:
//! 1. **rule-lock-tallies** — each rule's `locks_ok/replicating/stuck`
//!    counters equal the actual lock rows, and the rule state is the one
//!    derived from them;
//! 2. **ok-rule-backing** — no rule is `Ok` while a lock of it points at
//!    a missing, bad, or still-copying replica;
//! 3. **replica-lock-counts** — `replica.lock_count` equals the number of
//!    lock rows on it, and a locked replica never carries a tombstone;
//! 4. **usage-equals-locks** — per (account, RSE), the usage table equals
//!    the sum of that account's rule locks ("accounts are only charged
//!    for the files they actively set replication rules on", §2.5);
//! 5. **live-requests** — every non-terminal transfer request references
//!    a live rule and an existing destination RSE;
//! 6. **counter-agreement** — every table's O(1) row counter (what the
//!    monitoring [`crate::db::Registry`] reports) equals an actual row
//!    count of the table;
//! 7. **vo-isolation** — no row leaks across tenants: every scope lives
//!    in its owning account's VO and every token is pinned to its
//!    account's VO (the query layer filters by scope VO, so a consistent
//!    scope→VO mapping is exactly what "no query path returns
//!    foreign-VO rows" rests on);
//! 8. **vo-usage-rollup** — global usage equals the Σ of per-VO usage
//!    equals the Σ of per-VO lock charges (rule → account → VO), so
//!    tenant accounting never loses or double-counts a byte;
//! 9. **cache-rule-backing** — every C3PO cache replica is rule-backed:
//!    each "Dynamic Placement" rule carries a lifetime (so the reaper can
//!    reclaim cold caches) and its locks point at real replicas, i.e. the
//!    heat-driven placement loop never leaks unaccounted cache copies;
//! 10. **heat-agreement** — the decayed heat table and the lifetime
//!    popularity table agree: both are fed by the same read-trace path,
//!    so they hold rows for exactly the same DIDs and identical raw
//!    access tallies.

use std::collections::BTreeMap;

use crate::core::types::{LockState, ReplicaState, RequestState, RuleState};
use crate::core::Catalog;
use crate::db::{Row, Table};

/// One violated invariant, with enough detail to debug the run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub invariant: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// Run the full invariant set against a catalog. Returns every violation
/// found (empty = consistent).
pub fn check(cat: &Catalog) -> Vec<Violation> {
    let mut out = Vec::new();
    check_rule_lock_tallies(cat, &mut out);
    check_ok_rule_backing(cat, &mut out);
    check_replica_lock_counts(cat, &mut out);
    check_usage_equals_locks(cat, &mut out);
    check_live_requests(cat, &mut out);
    check_counter_agreement(cat, &mut out);
    check_vo_isolation(cat, &mut out);
    check_vo_usage_rollup(cat, &mut out);
    check_cache_rule_backing(cat, &mut out);
    check_heat_agreement(cat, &mut out);
    out
}

/// Deployment-level invariant (transfer orchestration v2): on every FTS
/// server, the number of **active** transfers per directed link never
/// exceeds that server's configured per-link concurrency cap — however
/// hard the throttler, a saturation storm, or a recovering backlog pushes.
/// Needs the deployment context (FTS handles live outside the catalog),
/// so it is a separate entry point; the chaos driver runs it alongside
/// [`check`] on every invariant cycle.
pub fn check_fts_link_caps(ctx: &crate::daemons::Ctx) -> Vec<Violation> {
    let mut out = Vec::new();
    for fts in &ctx.fts {
        for ((src, dst), active) in fts.active_per_link() {
            if active > fts.max_active_per_link {
                out.push(Violation {
                    invariant: "fts-link-caps",
                    detail: format!(
                        "{}: {active} active transfers on {src}→{dst} exceed the cap {}",
                        fts.name, fts.max_active_per_link
                    ),
                });
            }
        }
    }
    out
}

fn check_rule_lock_tallies(cat: &Catalog, out: &mut Vec<Violation>) {
    // (rule_id -> [ok, replicating, stuck]) from the actual lock rows.
    let mut tallies: BTreeMap<u64, [u32; 3]> = BTreeMap::new();
    cat.locks.for_each(|l| {
        let t = tallies.entry(l.rule_id).or_insert([0, 0, 0]);
        match l.state {
            LockState::Ok => t[0] += 1,
            LockState::Replicating => t[1] += 1,
            LockState::Stuck => t[2] += 1,
        }
    });
    cat.rules.for_each(|r| {
        let [ok, repl, stuck] = tallies.remove(&r.id).unwrap_or([0, 0, 0]);
        if (r.locks_ok, r.locks_replicating, r.locks_stuck) != (ok, repl, stuck) {
            out.push(Violation {
                invariant: "rule-lock-tallies",
                detail: format!(
                    "rule {} tallies ({},{},{}) != lock rows ({ok},{repl},{stuck})",
                    r.id, r.locks_ok, r.locks_replicating, r.locks_stuck
                ),
            });
        }
        let derived = if stuck > 0 {
            RuleState::Stuck
        } else if repl > 0 {
            RuleState::Replicating
        } else {
            RuleState::Ok
        };
        if r.state != derived && r.state != RuleState::Suspended {
            out.push(Violation {
                invariant: "rule-lock-tallies",
                detail: format!(
                    "rule {} state {:?} != derived {:?} from locks ({ok},{repl},{stuck})",
                    r.id, r.state, derived
                ),
            });
        }
    });
    // Orphan locks: a lock row whose rule no longer exists.
    for (rule_id, t) in tallies {
        out.push(Violation {
            invariant: "rule-lock-tallies",
            detail: format!("{} lock(s) reference missing rule {rule_id}", t.iter().sum::<u32>()),
        });
    }
}

fn check_ok_rule_backing(cat: &Catalog, out: &mut Vec<Violation>) {
    cat.rules.for_each(|r| {
        if r.state != RuleState::Ok {
            return;
        }
        for lock_key in cat.locks_by_rule.get(&r.id) {
            let Some(lock) = cat.locks.get(&lock_key) else { continue };
            match cat.replicas.get(&(lock.rse.clone(), lock.did.clone())) {
                None => out.push(Violation {
                    invariant: "ok-rule-backing",
                    detail: format!(
                        "rule {} is OK but its lock on {}@{} has no replica",
                        r.id, lock.did, lock.rse
                    ),
                }),
                // Suspicious replicas are degraded but still present and
                // readable; Bad/Copying cannot back an OK rule.
                Some(rep)
                    if matches!(rep.state, ReplicaState::Bad | ReplicaState::Copying) =>
                {
                    out.push(Violation {
                        invariant: "ok-rule-backing",
                        detail: format!(
                            "rule {} is OK but replica {}@{} is {:?}",
                            r.id, lock.did, lock.rse, rep.state
                        ),
                    })
                }
                Some(_) => {}
            }
        }
    });
}

fn check_replica_lock_counts(cat: &Catalog, out: &mut Vec<Violation>) {
    let mut counts: BTreeMap<(String, crate::core::types::DidKey), u32> = BTreeMap::new();
    cat.locks.for_each(|l| {
        *counts.entry((l.rse.clone(), l.did.clone())).or_insert(0) += 1;
    });
    cat.replicas.for_each(|r| {
        let n = counts
            .remove(&(r.rse.clone(), r.did.clone()))
            .unwrap_or(0);
        if r.lock_count != n {
            out.push(Violation {
                invariant: "replica-lock-counts",
                detail: format!(
                    "replica {}@{} lock_count {} != {} lock rows",
                    r.did, r.rse, r.lock_count, n
                ),
            });
        }
        if r.lock_count > 0 && r.tombstone.is_some() {
            out.push(Violation {
                invariant: "replica-lock-counts",
                detail: format!("locked replica {}@{} carries a tombstone", r.did, r.rse),
            });
        }
    });
    // Locks on replicas that do not exist are legitimate only in STUCK
    // state (the necromancer removed the copy; repair will relocate).
    for ((rse, did), _) in counts {
        let any_non_stuck = cat
            .locks_by_replica
            .get(&(rse.clone(), did.clone()))
            .into_iter()
            .filter_map(|k| cat.locks.get(&k))
            .any(|l| l.state != LockState::Stuck);
        if any_non_stuck {
            out.push(Violation {
                invariant: "replica-lock-counts",
                detail: format!("non-stuck lock(s) on missing replica {did}@{rse}"),
            });
        }
    }
}

fn check_usage_equals_locks(cat: &Catalog, out: &mut Vec<Violation>) {
    let mut rule_account: BTreeMap<u64, String> = BTreeMap::new();
    cat.rules.for_each(|r| {
        rule_account.insert(r.id, r.account.clone());
    });
    // (account, rse) -> (bytes, files) expected from lock rows.
    let mut expect: BTreeMap<(String, String), (u64, u64)> = BTreeMap::new();
    cat.locks.for_each(|l| {
        if let Some(acc) = rule_account.get(&l.rule_id) {
            let e = expect.entry((acc.clone(), l.rse.clone())).or_insert((0, 0));
            e.0 += l.bytes;
            e.1 += 1;
        }
    });
    cat.usages.for_each(|u| {
        let (bytes, files) = expect
            .remove(&(u.account.clone(), u.rse.clone()))
            .unwrap_or((0, 0));
        if u.bytes != bytes || u.files != files {
            out.push(Violation {
                invariant: "usage-equals-locks",
                detail: format!(
                    "usage {}@{} = ({}, {}) but locks sum to ({bytes}, {files})",
                    u.account, u.rse, u.bytes, u.files
                ),
            });
        }
    });
    // Locks charged to an (account, rse) with no usage row at all.
    for ((account, rse), (bytes, files)) in expect {
        if bytes > 0 || files > 0 {
            out.push(Violation {
                invariant: "usage-equals-locks",
                detail: format!(
                    "locks sum to ({bytes}, {files}) for {account}@{rse} but no usage row exists"
                ),
            });
        }
    }
}

fn check_vo_isolation(cat: &Catalog, out: &mut Vec<Violation>) {
    let mut account_vo: BTreeMap<String, String> = BTreeMap::new();
    cat.accounts.for_each(|a| {
        account_vo.insert(a.name.clone(), a.vo.clone());
    });
    cat.scopes.for_each(|s| match account_vo.get(&s.account) {
        Some(vo) if *vo == s.vo => {}
        Some(vo) => out.push(Violation {
            invariant: "vo-isolation",
            detail: format!(
                "scope {} is in VO {} but its owner {} is in VO {vo}",
                s.name, s.vo, s.account
            ),
        }),
        None => out.push(Violation {
            invariant: "vo-isolation",
            detail: format!("scope {} owned by missing account {}", s.name, s.account),
        }),
    });
    cat.tokens.for_each(|t| match account_vo.get(&t.account) {
        Some(vo) if *vo == t.vo => {}
        Some(vo) => out.push(Violation {
            invariant: "vo-isolation",
            detail: format!(
                "token of {} is pinned to VO {} but the account is in VO {vo}",
                t.account, t.vo
            ),
        }),
        None => out.push(Violation {
            invariant: "vo-isolation",
            detail: format!("token references missing account {}", t.account),
        }),
    });
}

fn check_vo_usage_rollup(cat: &Catalog, out: &mut Vec<Violation>) {
    // Global totals straight off the usage rows.
    let (mut g_bytes, mut g_files) = (0u64, 0u64);
    cat.usages.for_each(|u| {
        g_bytes += u.bytes;
        g_files += u.files;
    });
    let roll = cat.vo_usage();
    let v_bytes: u64 = roll.values().map(|(b, _)| *b).sum();
    let v_files: u64 = roll.values().map(|(_, f)| *f).sum();
    if (g_bytes, g_files) != (v_bytes, v_files) {
        out.push(Violation {
            invariant: "vo-usage-rollup",
            detail: format!(
                "global usage ({g_bytes} B, {g_files} files) != Σ per-VO usage \
                 ({v_bytes} B, {v_files} files)"
            ),
        });
    }
    // Per-VO lock charges: rule → account → VO.
    let mut account_vo: BTreeMap<String, String> = BTreeMap::new();
    cat.accounts.for_each(|a| {
        account_vo.insert(a.name.clone(), a.vo.clone());
    });
    let mut rule_vo: BTreeMap<u64, String> = BTreeMap::new();
    cat.rules.for_each(|r| {
        let vo = account_vo
            .get(&r.account)
            .cloned()
            .unwrap_or_else(|| crate::core::types::DEFAULT_VO.to_string());
        rule_vo.insert(r.id, vo);
    });
    let mut lock_roll: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    cat.locks.for_each(|l| {
        if let Some(vo) = rule_vo.get(&l.rule_id) {
            let e = lock_roll.entry(vo.clone()).or_insert((0, 0));
            e.0 += l.bytes;
            e.1 += 1;
        }
    });
    for vo in roll.keys().chain(lock_roll.keys()).collect::<std::collections::BTreeSet<_>>() {
        let u = roll.get(vo.as_str()).copied().unwrap_or((0, 0));
        let l = lock_roll.get(vo.as_str()).copied().unwrap_or((0, 0));
        if u != l {
            out.push(Violation {
                invariant: "vo-usage-rollup",
                detail: format!(
                    "VO {vo}: usage rollup ({}, {}) != lock charges ({}, {})",
                    u.0, u.1, l.0, l.1
                ),
            });
        }
    }
}

fn check_live_requests(cat: &Catalog, out: &mut Vec<Violation>) {
    for state in [
        RequestState::Waiting,
        RequestState::Queued,
        RequestState::Submitted,
        RequestState::Retry,
    ] {
        for id in cat.requests_by_state.get(&state) {
            let Some(req) = cat.requests.get(&id) else { continue };
            if !cat.rules.contains(&req.rule_id) {
                out.push(Violation {
                    invariant: "live-requests",
                    detail: format!(
                        "{state:?} request {} references missing rule {}",
                        req.id, req.rule_id
                    ),
                });
            }
            if cat.rses.get(&req.dst_rse).is_none() {
                out.push(Violation {
                    invariant: "live-requests",
                    detail: format!(
                        "{state:?} request {} targets unknown RSE {}",
                        req.id, req.dst_rse
                    ),
                });
            }
        }
    }
}

fn check_counter_agreement(cat: &Catalog, out: &mut Vec<Violation>) {
    fn one<V: Row>(t: &Table<V>, out: &mut Vec<Violation>) {
        let mut actual = 0usize;
        t.for_each(|_| actual += 1);
        if t.len() != actual {
            out.push(Violation {
                invariant: "counter-agreement",
                detail: format!("table {} counter {} != {} actual rows", t.name(), t.len(), actual),
            });
        }
    }
    one(&cat.accounts, out);
    one(&cat.identities, out);
    one(&cat.tokens, out);
    one(&cat.scopes, out);
    one(&cat.dids, out);
    one(&cat.attachments, out);
    one(&cat.name_tombstones, out);
    one(&cat.rses, out);
    one(&cat.distances, out);
    one(&cat.replicas, out);
    one(&cat.bad_replicas, out);
    one(&cat.rules, out);
    one(&cat.locks, out);
    one(&cat.requests, out);
    one(&cat.limits, out);
    one(&cat.usages, out);
    one(&cat.subscriptions, out);
    one(&cat.outbox, out);
    one(&cat.popularity, out);
    one(&cat.heat, out);
    // ...and the monitoring registry reports exactly those counters.
    let snap = cat.registry.snapshot();
    for (name, len) in [
        ("replicas", cat.replicas.len()),
        ("rules", cat.rules.len()),
        ("locks", cat.locks.len()),
        ("requests", cat.requests.len()),
    ] {
        if snap.get(name).copied() != Some(len) {
            out.push(Violation {
                invariant: "counter-agreement",
                detail: format!("registry reports {:?} for {name}, table says {len}", snap.get(name)),
            });
        }
    }
}

/// C3PO cache replicas are always rule-backed (§6.1): every rule the
/// placement daemon issued (activity "Dynamic Placement") must carry an
/// expiry — that is the whole reclamation contract with the reaper — and
/// each of its non-stuck locks must point at an existing replica row, so
/// a cache copy can never outlive its rule unaccounted.
fn check_cache_rule_backing(cat: &Catalog, out: &mut Vec<Violation>) {
    cat.rules.for_each(|r| {
        if r.activity != crate::placement::CACHE_ACTIVITY {
            return;
        }
        if r.expires_at.is_none() {
            out.push(Violation {
                invariant: "cache-rule-backing",
                detail: format!(
                    "cache rule {} on {} has no lifetime — the reaper can never reclaim it",
                    r.id, r.rse_expression
                ),
            });
        }
        for lock_key in cat.locks_by_rule.get(&r.id) {
            let Some(lock) = cat.locks.get(&lock_key) else { continue };
            if lock.state != LockState::Stuck
                && cat.replicas.get(&(lock.rse.clone(), lock.did.clone())).is_none()
            {
                out.push(Violation {
                    invariant: "cache-rule-backing",
                    detail: format!(
                        "cache rule {} lock on {}@{} has no replica behind it",
                        r.id, lock.did, lock.rse
                    ),
                });
            }
        }
    });
}

/// The decayed heat table and the lifetime popularity table are fed by
/// the same read-trace path, in lock-step: they must cover exactly the
/// same DIDs with identical raw access tallies, and every heat score
/// must be a finite non-negative number.
fn check_heat_agreement(cat: &Catalog, out: &mut Vec<Violation>) {
    let mut pop: BTreeMap<crate::core::types::DidKey, u64> = BTreeMap::new();
    cat.popularity.for_each(|p| {
        pop.insert(p.did.clone(), p.accesses);
    });
    cat.heat.for_each(|h| {
        match pop.remove(&h.did) {
            Some(accesses) if accesses == h.accesses => {}
            Some(accesses) => out.push(Violation {
                invariant: "heat-agreement",
                detail: format!(
                    "{}: heat counts {} accesses but popularity counts {accesses}",
                    h.did, h.accesses
                ),
            }),
            None => out.push(Violation {
                invariant: "heat-agreement",
                detail: format!("{} has a heat row but no popularity row", h.did),
            }),
        }
        if !h.score.is_finite() || h.score < 0.0 {
            out.push(Violation {
                invariant: "heat-agreement",
                detail: format!("{} has a degenerate heat score {}", h.did, h.score),
            });
        }
    });
    for (did, _) in pop {
        out.push(Violation {
            invariant: "heat-agreement",
            detail: format!("{did} has a popularity row but no heat row"),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rse::Rse;
    use crate::core::rules_api::RuleSpec;
    use crate::core::types::DidKey;

    fn catalog() -> Catalog {
        let c = Catalog::new_for_tests();
        let now = c.now();
        c.add_scope("data18", "root").unwrap();
        for name in ["A-DISK", "B-DISK"] {
            c.add_rse(Rse::new(name, now)).unwrap();
        }
        c
    }

    #[test]
    fn clean_catalog_has_no_violations() {
        let c = catalog();
        assert_eq!(check(&c), Vec::new());
    }

    #[test]
    fn busy_catalog_stays_consistent_through_lifecycle() {
        let c = catalog();
        for i in 0..5 {
            c.add_file("data18", &format!("f{i}"), "root", 100 + i, "aabbccdd", None)
                .unwrap();
        }
        c.add_replica("A-DISK", &DidKey::new("data18", "f0"), ReplicaState::Available, None)
            .unwrap();
        let mut rules = Vec::new();
        for i in 0..5 {
            rules.push(
                c.add_rule(RuleSpec::new("root", DidKey::new("data18", &format!("f{i}")), "B-DISK", 1))
                    .unwrap(),
            );
        }
        assert_eq!(check(&c), Vec::new());
        // drive some to completion, some to failure, one rule away
        for (i, req) in c.requests.scan(|_| true).into_iter().enumerate() {
            if i % 2 == 0 {
                c.on_transfer_done(req.id).unwrap();
            } else {
                for _ in 0..3 {
                    c.on_transfer_failed(req.id, "DESTINATION broken").unwrap();
                }
            }
        }
        c.delete_rule(rules[0]).unwrap();
        assert_eq!(check(&c), Vec::new());
    }

    #[test]
    fn tampering_is_detected() {
        let c = catalog();
        c.add_file("data18", "f0", "root", 100, "aabbccdd", None).unwrap();
        let f = DidKey::new("data18", "f0");
        c.add_replica("A-DISK", &f, ReplicaState::Available, None).unwrap();
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "A-DISK", 1)).unwrap();
        assert_eq!(check(&c), Vec::new());
        // break the tally behind the API's back
        c.rules.update(&rid, c.now(), |r| r.locks_ok += 1);
        let v = check(&c);
        assert!(
            v.iter().any(|x| x.invariant == "rule-lock-tallies"),
            "tampered tallies detected: {v:?}"
        );
        // fix it back, then break usage
        c.rules.update(&rid, c.now(), |r| r.locks_ok -= 1);
        c.usages.update(&("root".to_string(), "A-DISK".to_string()), c.now(), |u| {
            u.bytes += 7
        });
        let v = check(&c);
        assert!(v.iter().any(|x| x.invariant == "usage-equals-locks"), "{v:?}");
    }

    #[test]
    fn multi_vo_catalog_consistent_and_leaks_detected() {
        use crate::core::types::AccountType;
        let c = catalog();
        c.add_account_vo("at1", AccountType::User, "", "atlas").unwrap();
        c.add_account_vo("cm1", AccountType::User, "", "cms").unwrap();
        c.add_scope("s-atlas", "at1").unwrap();
        c.add_scope("s-cms", "cm1").unwrap();
        for (scope, owner) in [("s-atlas", "at1"), ("s-cms", "cm1")] {
            c.add_file(scope, "f0", owner, 100, "aabbccdd", None).unwrap();
            c.add_replica("A-DISK", &DidKey::new(scope, "f0"), ReplicaState::Available, None)
                .unwrap();
            c.add_rule(RuleSpec::new(owner, DidKey::new(scope, "f0"), "A-DISK", 1)).unwrap();
        }
        c.add_identity("at1", crate::core::types::AuthType::UserPass, "at1", Some("pw"))
            .unwrap();
        c.auth_userpass("at1", "at1", "pw").unwrap();
        assert_eq!(check(&c), Vec::new());
        // a scope drifting out of its owner's VO is a tenant leak
        c.scopes.update(&"s-cms".to_string(), c.now(), |s| s.vo = "atlas".into());
        let v = check(&c);
        assert!(v.iter().any(|x| x.invariant == "vo-isolation"), "{v:?}");
        c.scopes.update(&"s-cms".to_string(), c.now(), |s| s.vo = "cms".into());
        // an account switching VO under live usage breaks the rollup
        c.accounts.update(&"cm1".to_string(), c.now(), |a| a.vo = "atlas".into());
        let v = check(&c);
        assert!(v.iter().any(|x| x.invariant == "vo-isolation"), "{v:?}");
    }

    #[test]
    fn fts_link_cap_check_sees_overload() {
        use crate::daemons::conveyor::tests::{rig, seed_file};
        use crate::daemons::conveyor::Submitter;
        use crate::daemons::Daemon;
        let (ctx, cat) = rig();
        for i in 0..6 {
            let f = seed_file(&ctx, &format!("cap{i}"), 50_000_000);
            cat.add_rule(RuleSpec::new("root", f, "DST-A", 1)).unwrap();
        }
        let mut submitter = Submitter::new(ctx.clone(), "s1");
        submitter.tick(cat.now());
        for fts in &ctx.fts {
            fts.advance(cat.now());
        }
        // 6 concurrent transfers on one link, default cap 20: no violation
        assert_eq!(check_fts_link_caps(&ctx), Vec::new());
        assert!(ctx.fts[0].active_count() > 0);
    }

    #[test]
    fn bad_replica_under_ok_rule_is_flagged() {
        let c = catalog();
        c.add_file("data18", "f0", "root", 100, "aabbccdd", None).unwrap();
        let f = DidKey::new("data18", "f0");
        c.add_replica("A-DISK", &f, ReplicaState::Available, None).unwrap();
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "A-DISK", 1)).unwrap();
        // flip the replica bad *without* the declare_bad bookkeeping
        c.replicas.update(&("A-DISK".to_string(), f.clone()), c.now(), |r| {
            r.state = ReplicaState::Bad
        });
        let v = check(&c);
        assert!(v.iter().any(|x| x.invariant == "ok-rule-backing"), "{v:?}");
        // the API path keeps the invariant: declare_bad sticks the locks
        let c2 = catalog();
        c2.add_file("data18", "f0", "root", 100, "aabbccdd", None).unwrap();
        c2.add_replica("A-DISK", &f, ReplicaState::Available, None).unwrap();
        let _ = c2.add_rule(RuleSpec::new("root", f.clone(), "A-DISK", 1)).unwrap();
        c2.declare_bad("A-DISK", &f, "rot", "ops").unwrap();
        assert_eq!(check(&c2), Vec::new());
        let _ = rid;
    }
}
