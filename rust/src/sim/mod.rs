//! Simulation: the ATLAS-like grid ([`grid`]), the synthetic workload
//! generator ([`workload`]), the discrete-event driver ([`driver`])
//! that runs the full stack — catalog, daemons, FTS, network, storage —
//! under virtual time to regenerate the paper's evaluation figures, the
//! chaos scenario engine ([`scenario`]) that injects declarative fault
//! timelines into a run, and the system-invariant checker
//! ([`invariants`]) that proves the bookkeeping survives them.

pub mod campaign;
pub mod driver;
pub mod grid;
pub mod invariants;
pub mod scenario;
pub mod workload;
