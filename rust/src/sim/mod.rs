//! Simulation: the ATLAS-like grid ([`grid`]), the synthetic workload
//! generator ([`workload`]), and the discrete-event driver ([`driver`])
//! that runs the full stack — catalog, daemons, FTS, network, storage —
//! under virtual time to regenerate the paper's evaluation figures.

pub mod driver;
pub mod grid;
pub mod workload;
