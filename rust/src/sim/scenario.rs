//! Chaos scenario engine: declarative fault timelines for the simulated
//! grid.
//!
//! A [`Scenario`] is a list of `(virtual-time offset, Event)` pairs; the
//! discrete-event driver ([`crate::sim::driver::Driver`]) applies each
//! event when the simulation clock reaches it. Events cover the incident
//! classes a production data grid lives with (Dynamo and AAA both call
//! site outages and degraded links the *normal* operating mode):
//!
//! * RSE outage / recovery / drain — availability toggles in the catalog
//!   plus a hard storage-endpoint outage;
//! * inter-region network degradation and partition — fault overlays on
//!   the [`crate::netsim::Network`] link table;
//! * corruption bursts on one storage endpoint — bit rot on stored files,
//!   detected as checksum mismatches, recovered by the necromancer;
//! * FTS server downtime — the conveyor routes around dead servers, a
//!   full blackout queues a backlog that drains on recovery;
//! * daemon-instance crash/restart — the driver stops ticking the
//!   instance, its heartbeat expires, the hash ring rebalances (§3.4);
//! * tape-recall storms — a burst of staging rules against archived RAW
//!   datasets, pressuring the tape robots.
//!
//! Events are deliberately *mechanism-level* (they flip the same toggles
//! an operator or a real incident would), so every recovery path runs
//! through the production code: retries, repair, failover, auditing.

use crate::common::clock::{DAY_MS, EpochMs};
use crate::core::rules_api::RuleSpec;
use crate::core::types::DidType;
use crate::daemons::Ctx;
use crate::netsim::LinkFault;

/// One fault (or recovery) applied at a point in virtual time.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Full site outage: catalog availability off, storage endpoint hard
    /// down. Replicas survive on disk; transfers from/to the RSE fail.
    RseDown { rse: String },
    /// Recovery: availability restored, endpoint back online.
    RseUp { rse: String },
    /// Drain: stop placing new data on the RSE; reads/deletes continue.
    RseDrain { rse: String },
    /// Undrain: the RSE accepts writes again.
    RseUndrain { rse: String },
    /// Degrade every link between two regions: quality multiplied by
    /// `quality_mult`, bandwidth divided by `bandwidth_div`.
    NetworkDegrade {
        src_region: String,
        dst_region: String,
        quality_mult: f64,
        bandwidth_div: u64,
    },
    /// Full bidirectional partition between two regions.
    NetworkPartition { region_a: String, region_b: String },
    /// Clear all fault overlays between two regions (both directions).
    NetworkRestore { region_a: String, region_b: String },
    /// Corrupt up to `files` stored files on one endpoint (bit rot).
    CorruptionBurst { rse: String, files: usize },
    /// Take the `index`-th FTS server down / up.
    FtsDown { index: usize },
    FtsUp { index: usize },
    /// Crash the `which`-th daemon instance whose `Daemon::name()` equals
    /// `daemon` — it stops ticking and its heartbeat goes silent.
    DaemonCrash { daemon: String, which: usize },
    /// Restart a crashed instance: it resumes ticking (and beating).
    DaemonRestart { daemon: String, which: usize },
    /// Crash the whole catalog process: the driver *drops* the live
    /// in-memory catalog and cold-boots a replacement from the
    /// durability directory (WAL + snapshots), then restarts the daemon
    /// fleet against the recovered state and runs the full invariant
    /// suite. Requires `[db] wal_dir`; ignored (with a warning) on
    /// non-durable catalogs.
    ProcessCrash,
    /// Recall storm: staging rules for up to `datasets` archived RAW
    /// datasets onto Tier-1 disk (activity "Staging", 7-day lifetime).
    TapeRecallStorm { datasets: usize },
    /// Flash crowd: one dataset goes viral — a burst of `accesses` read
    /// traces against its files lands at once (round-robin over the
    /// files, each read served from a live replica). The tracer folds
    /// the burst into popularity + decayed heat, and the C3PO daemon
    /// converts the heat into short-lived cache replicas that the reaper
    /// reclaims once the crowd passes.
    FlashCrowd { scope: String, name: String, accesses: usize },
    /// Link-saturation storm: a burst of single-activity replication
    /// rules flooding one destination (`rse_expression`), so its inbound
    /// links hit the throttler's admission caps and the FTS per-link
    /// concurrency limits — the backpressure path of transfer
    /// orchestration v2. 7-day lifetime so the flood eventually drains.
    LinkSaturationStorm {
        rse_expression: String,
        datasets: usize,
        activity: String,
    },
}

/// A named fault timeline. Offsets are virtual milliseconds from the
/// moment the scenario is scheduled on a driver.
#[derive(Debug, Clone, Default)]
pub struct Scenario {
    pub name: String,
    pub events: Vec<(i64, Event)>,
}

impl Scenario {
    pub fn new(name: &str) -> Self {
        Scenario { name: name.to_string(), events: Vec::new() }
    }

    /// Add an event at `offset_ms` after scenario start (builder).
    pub fn at(mut self, offset_ms: i64, event: Event) -> Self {
        self.events.push((offset_ms, event));
        self
    }

    /// Convenience: offset in virtual hours.
    pub fn at_hours(self, hours: i64, event: Event) -> Self {
        self.at(hours * crate::common::clock::HOUR_MS, event)
    }
}

/// Sites of every RSE in a region (the network is keyed by site).
fn region_sites(ctx: &Ctx, region: &str) -> Vec<String> {
    ctx.catalog
        .list_rses()
        .into_iter()
        .filter(|r| r.attr("region") == Some(region))
        .map(|r| r.site().to_string())
        .collect()
}

fn fault_region_pair(ctx: &Ctx, a: &str, b: &str, fault: Option<LinkFault>) {
    for sa in region_sites(ctx, a) {
        for sb in region_sites(ctx, b) {
            if sa == sb {
                continue;
            }
            match fault {
                Some(f) => ctx.net.set_fault_bidir(&sa, &sb, f),
                None => ctx.net.clear_fault_bidir(&sa, &sb),
            }
        }
    }
}

/// Apply one deployment-level event. Daemon crash/restart events are the
/// driver's job (it owns the daemon fleet) and are ignored here.
pub fn apply(ctx: &Ctx, event: &Event, now: EpochMs) {
    let cat = &ctx.catalog;
    match event {
        Event::RseDown { rse } => {
            let _ = cat.set_rse_availability(rse, false, false, false);
            if let Some(sys) = ctx.fleet.get(rse) {
                sys.set_offline(true);
            }
            cat.metrics.incr("scenario.rse_down", 1);
        }
        Event::RseUp { rse } => {
            // Recovery restores availability — but an administrative drain
            // that predates (or overlaps) the outage stays in force.
            let drained = cat.rse_is_drained(rse);
            let _ = cat.set_rse_availability(rse, true, !drained, true);
            if let Some(sys) = ctx.fleet.get(rse) {
                sys.set_offline(false);
            }
            cat.metrics.incr("scenario.rse_up", 1);
        }
        Event::RseDrain { rse } => {
            let _ = cat.set_rse_drain(rse, true);
        }
        Event::RseUndrain { rse } => {
            let _ = cat.set_rse_drain(rse, false);
        }
        Event::NetworkDegrade { src_region, dst_region, quality_mult, bandwidth_div } => {
            fault_region_pair(
                ctx,
                src_region,
                dst_region,
                Some(LinkFault::degraded(*quality_mult, *bandwidth_div)),
            );
        }
        Event::NetworkPartition { region_a, region_b } => {
            fault_region_pair(ctx, region_a, region_b, Some(LinkFault::partition()));
        }
        Event::NetworkRestore { region_a, region_b } => {
            fault_region_pair(ctx, region_a, region_b, None);
        }
        Event::CorruptionBurst { rse, files } => {
            if let Some(sys) = ctx.fleet.get(rse) {
                let victims: Vec<String> =
                    sys.dump().into_iter().map(|(pfn, _)| pfn).take(*files).collect();
                for pfn in victims {
                    sys.corrupt(&pfn);
                }
            }
            cat.metrics.incr("scenario.corruption_burst", 1);
        }
        Event::FtsDown { index } => {
            if let Some(fts) = ctx.fts.get(*index) {
                fts.set_online(false);
            }
        }
        Event::FtsUp { index } => {
            if let Some(fts) = ctx.fts.get(*index) {
                fts.set_online(true);
            }
        }
        Event::DaemonCrash { .. } | Event::DaemonRestart { .. } | Event::ProcessCrash => {
            // handled by the driver, which owns the daemon fleet and the
            // catalog handle
        }
        Event::FlashCrowd { scope, name, accesses } => {
            let ds = crate::core::types::DidKey::new(scope, name);
            let files = cat.resolve_files(&ds);
            let mut emitted = 0usize;
            if !files.is_empty() {
                for i in 0..*accesses {
                    let f = &files[i % files.len()];
                    let Some(rep) = cat.available_replicas(&f.key).into_iter().next() else {
                        continue;
                    };
                    crate::daemons::tracer::emit_trace(
                        &ctx.broker,
                        now,
                        "download",
                        &rep.rse,
                        &f.key.scope,
                        &f.key.name,
                    );
                    emitted += 1;
                }
            }
            cat.metrics.incr("scenario.flash_crowd_traces", emitted as u64);
        }
        Event::LinkSaturationStorm { rse_expression, datasets, activity } => {
            let mut issued = 0;
            for d in cat.list_dids("data18", None, Some(DidType::Dataset), false) {
                if issued >= *datasets {
                    break;
                }
                if cat
                    .add_rule(
                        RuleSpec::new("root", d.key.clone(), rse_expression, 1)
                            .with_lifetime(7 * DAY_MS)
                            .with_activity(activity),
                    )
                    .is_ok()
                {
                    issued += 1;
                }
            }
            cat.metrics.incr("scenario.saturation_rules", issued as u64);
        }
        Event::TapeRecallStorm { datasets } => {
            let mut issued = 0;
            for d in cat.list_dids("data18", Some("raw.*"), Some(DidType::Dataset), false) {
                if issued >= *datasets {
                    break;
                }
                if cat
                    .add_rule(
                        RuleSpec::new("root", d.key.clone(), "tier=1&type=disk", 1)
                            .with_lifetime(7 * DAY_MS)
                            .with_activity("Staging"),
                    )
                    .is_ok()
                {
                    issued += 1;
                }
            }
            cat.metrics.incr("scenario.recall_storm_rules", issued as u64);
        }
    }
    let _ = now;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::clock::{Clock, HOUR_MS};
    use crate::common::config::Config;
    use crate::sim::grid::{build_grid, GridSpec};

    fn ctx() -> Ctx {
        build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new())
    }

    #[test]
    fn builder_orders_events() {
        let sc = Scenario::new("demo")
            .at_hours(2, Event::RseDown { rse: "DE-T1-DISK".into() })
            .at_hours(10, Event::RseUp { rse: "DE-T1-DISK".into() });
        assert_eq!(sc.events.len(), 2);
        assert_eq!(sc.events[0].0, 2 * HOUR_MS);
        assert_eq!(sc.name, "demo");
    }

    #[test]
    fn rse_down_and_up_toggle_catalog_and_storage() {
        let ctx = ctx();
        apply(&ctx, &Event::RseDown { rse: "DE-T1-DISK".into() }, 0);
        let rse = ctx.catalog.get_rse("DE-T1-DISK").unwrap();
        assert!(!rse.availability_write && !rse.availability_read);
        assert!(ctx.fleet.get("DE-T1-DISK").unwrap().is_offline());
        apply(&ctx, &Event::RseUp { rse: "DE-T1-DISK".into() }, 0);
        let rse = ctx.catalog.get_rse("DE-T1-DISK").unwrap();
        assert!(rse.availability_write && rse.availability_read);
        assert!(!ctx.fleet.get("DE-T1-DISK").unwrap().is_offline());
    }

    #[test]
    fn drain_only_blocks_writes() {
        let ctx = ctx();
        apply(&ctx, &Event::RseDrain { rse: "FR-T1-DISK".into() }, 0);
        let rse = ctx.catalog.get_rse("FR-T1-DISK").unwrap();
        assert!(rse.availability_read && !rse.availability_write && rse.availability_delete);
        assert!(!ctx.fleet.get("FR-T1-DISK").unwrap().is_offline());
        apply(&ctx, &Event::RseUndrain { rse: "FR-T1-DISK".into() }, 0);
        assert!(ctx.catalog.get_rse("FR-T1-DISK").unwrap().availability_write);
    }

    #[test]
    fn rse_up_respects_standing_drain() {
        let ctx = ctx();
        apply(&ctx, &Event::RseDrain { rse: "DE-T2-1".into() }, 0);
        apply(&ctx, &Event::RseDown { rse: "DE-T2-1".into() }, 0);
        apply(&ctx, &Event::RseUp { rse: "DE-T2-1".into() }, 0);
        let rse = ctx.catalog.get_rse("DE-T2-1").unwrap();
        assert!(rse.availability_read && rse.availability_delete);
        assert!(!rse.availability_write, "drain survives the outage recovery");
        apply(&ctx, &Event::RseUndrain { rse: "DE-T2-1".into() }, 0);
        assert!(ctx.catalog.get_rse("DE-T2-1").unwrap().availability_write);
    }

    #[test]
    fn partition_and_restore_cover_all_region_links() {
        let ctx = ctx();
        apply(
            &ctx,
            &Event::NetworkPartition { region_a: "FR".into(), region_b: "DE".into() },
            0,
        );
        assert_eq!(ctx.net.link("FR-T1-DISK", "DE-T1-DISK").quality, 0.0);
        assert_eq!(ctx.net.link("DE-T2-1", "FR-T2-2").quality, 0.0);
        assert!(ctx.net.fault_count() > 0);
        apply(
            &ctx,
            &Event::NetworkRestore { region_a: "FR".into(), region_b: "DE".into() },
            0,
        );
        assert_eq!(ctx.net.fault_count(), 0);
        assert!(ctx.net.link("FR-T1-DISK", "DE-T1-DISK").quality > 0.5);
    }

    #[test]
    fn fts_downtime_toggles() {
        let ctx = ctx();
        apply(&ctx, &Event::FtsDown { index: 0 }, 0);
        assert!(!ctx.fts[0].is_online());
        assert!(ctx.fts[1].is_online());
        apply(&ctx, &Event::FtsUp { index: 0 }, 0);
        assert!(ctx.fts[0].is_online());
        // out-of-range indexes are ignored
        apply(&ctx, &Event::FtsDown { index: 99 }, 0);
    }

    #[test]
    fn saturation_storm_floods_one_destination() {
        let ctx = ctx();
        let cat = &ctx.catalog;
        for i in 0..4 {
            cat.add_dataset("data18", &format!("sat.ds{i}"), "root").unwrap();
        }
        apply(
            &ctx,
            &Event::LinkSaturationStorm {
                rse_expression: "US-T1-DISK".into(),
                datasets: 3,
                activity: "Production".into(),
            },
            0,
        );
        assert_eq!(cat.metrics.counter("scenario.saturation_rules"), 3);
        let storm: Vec<_> = cat.rules.scan(|r| r.rse_expression == "US-T1-DISK");
        assert_eq!(storm.len(), 3);
        assert!(storm.iter().all(|r| r.activity == "Production"));
        assert!(storm.iter().all(|r| r.expires_at.is_some()));
    }

    #[test]
    fn flash_crowd_drives_heat_through_the_tracer() {
        use crate::core::types::{DidKey, ReplicaState};
        use crate::daemons::tracer::Tracer;
        use crate::daemons::Daemon;
        let ctx = ctx();
        let cat = &ctx.catalog;
        // subscribe before the burst so the tracer sees every message
        let mut tracer = Tracer::new(ctx.clone());
        cat.add_dataset("data18", "viral.ds", "root").unwrap();
        let ds = DidKey::new("data18", "viral.ds");
        for i in 0..2 {
            cat.add_file("data18", &format!("viral.f{i}"), "root", 100, "aabbccdd", None)
                .unwrap();
            let f = DidKey::new("data18", &format!("viral.f{i}"));
            cat.attach(&ds, &f).unwrap();
            cat.add_replica("DE-T1-DISK", &f, ReplicaState::Available, None).unwrap();
        }
        apply(
            &ctx,
            &Event::FlashCrowd { scope: "data18".into(), name: "viral.ds".into(), accesses: 10 },
            cat.now(),
        );
        assert_eq!(cat.metrics.counter("scenario.flash_crowd_traces"), 10);
        assert_eq!(tracer.tick(cat.now()), 10);
        assert_eq!(cat.popularity.get(&ds).unwrap().accesses, 10);
        assert!(cat.heat_score(&ds, cat.now()) >= 9.0, "the dataset is hot");
    }

    #[test]
    fn recall_storm_issues_staging_rules() {
        let ctx = ctx();
        let cat = &ctx.catalog;
        for i in 0..3 {
            cat.add_dataset("data18", &format!("raw.old{i}"), "root").unwrap();
        }
        apply(&ctx, &Event::TapeRecallStorm { datasets: 2 }, 0);
        assert_eq!(cat.metrics.counter("scenario.recall_storm_rules"), 2);
        let staging = cat.rules.scan(|r| r.activity == "Staging");
        assert_eq!(staging.len(), 2);
        assert!(staging.iter().all(|r| r.expires_at.is_some()));
    }
}
