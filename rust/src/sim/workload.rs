//! ATLAS-like workload generator: detector RAW streams, Monte-Carlo /
//! derivation production chains, and Zipf-skewed user analysis — the
//! dataflow shape behind Figs 6/10/11 and the §6.1 reuse statistics.

use crate::common::clock::{DAY_MS, EpochMs, HOUR_MS};
use crate::common::prng::Prng;
use crate::core::metaexpr::{self, MetaValue};
use crate::core::rules_api::RuleSpec;
use crate::core::types::{DidKey, ReplicaState};
use crate::daemons::Ctx;
use crate::storagesim::synthetic_adler32_for;

/// Detector streams tagged onto RAW datasets (metadata the discovery
/// queries select on).
const STREAMS: &[&str] = &["physics_Main", "physics_Late", "express_express"];

/// Multi-VO tenant population: several virtual organisations sharing one
/// catalog (the multi-VO operation mode), with heavy-tailed request
/// rates across them — the workload the per-VO throttler shares and the
/// tenant-isolation invariants are exercised against.
#[derive(Debug, Clone)]
pub struct MultiVoSpec {
    /// Tenant names (3–5 in the acceptance runs).
    pub vos: Vec<String>,
    /// Accounts provisioned per VO (each with a home scope and a
    /// userpass identity); thousands in total at default scale.
    pub accounts_per_vo: usize,
    /// Replication rules created per day across the population.
    pub rules_per_day: usize,
    /// Logins (token issues + validations) per day — auth churn.
    pub logins_per_day: usize,
    /// Zipf exponent for the VO pick: low-rank VOs dominate the request
    /// stream (heavy tail), the rest trickle.
    pub zipf_theta: f64,
}

impl Default for MultiVoSpec {
    fn default() -> Self {
        MultiVoSpec {
            vos: vec!["atlas".into(), "cms".into(), "belle".into()],
            accounts_per_vo: 700,
            rules_per_day: 96,
            logins_per_day: 192,
            zipf_theta: 1.2,
        }
    }
}

/// Workload scale knobs (all per simulated day unless noted).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// RAW datasets produced by the detector per day.
    pub raw_datasets_per_day: usize,
    /// Files per dataset.
    pub files_per_dataset: usize,
    /// Median file size (log-normal sigma 0.5).
    pub median_file_bytes: u64,
    /// Derivation jobs per day (RAW → AOD at a T1).
    pub derivations_per_day: usize,
    /// User analysis accesses per day (traces; Zipf over recent AODs).
    pub analysis_accesses_per_day: usize,
    /// Data-discovery queries per day (`meta-expr` filters over the
    /// namespace — the paper's metadata-driven lookup traffic; read-only
    /// but exercises the query planner under the live mutation load).
    pub discovery_queries_per_day: usize,
    /// AOD rule lifetime (drives the deletion workload).
    pub aod_lifetime_ms: i64,
    /// Days with boosted analysis (conference crunch, paper §5.3:
    /// "few bursts with the exception of weeks leading up to physics
    /// conferences") as (start_day, end_day, multiplier).
    pub burst: Option<(u32, u32, f64)>,
    /// Multi-VO tenant population riding on top of the ATLAS-shaped
    /// flow; `None` keeps the classic single-tenant workload.
    pub multi_vo: Option<MultiVoSpec>,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            raw_datasets_per_day: 12,
            files_per_dataset: 8,
            median_file_bytes: 2_000_000_000, // 2 GB
            derivations_per_day: 8,
            analysis_accesses_per_day: 120,
            discovery_queries_per_day: 48,
            aod_lifetime_ms: 20 * DAY_MS,
            burst: None,
            multi_vo: None,
            seed: 7,
        }
    }
}

/// Generator state.
pub struct Workload {
    pub spec: WorkloadSpec,
    rng: Prng,
    raw_count: u64,
    aod_count: u64,
    /// Recent AOD datasets (analysis targets), most recent last.
    pub aods: Vec<DidKey>,
    /// Recent RAW datasets awaiting derivation, with their run numbers
    /// (derivations inherit the run; discovery filters select on it).
    raws: Vec<(DidKey, i64)>,
    carry_raw: f64,
    carry_der: f64,
    carry_ana: f64,
    carry_disc: f64,
    /// Provisioned tenant accounts as (vo, account, home scope); empty
    /// until the first step of a multi-VO workload.
    pub vo_accounts: Vec<(String, String, String)>,
    vo_files: u64,
    carry_vo_rules: f64,
    carry_vo_logins: f64,
}

impl Workload {
    pub fn new(spec: WorkloadSpec) -> Self {
        let rng = Prng::new(spec.seed);
        Workload {
            spec,
            rng,
            raw_count: 0,
            aod_count: 0,
            aods: Vec::new(),
            raws: Vec::new(),
            carry_raw: 0.0,
            carry_der: 0.0,
            carry_ana: 0.0,
            carry_disc: 0.0,
            vo_accounts: Vec::new(),
            vo_files: 0,
            carry_vo_rules: 0.0,
            carry_vo_logins: 0.0,
        }
    }

    /// Advance the workload by `dt_ms` of virtual time at `now`, `day`
    /// being the simulation day index (for bursts).
    pub fn step(&mut self, ctx: &Ctx, now: EpochMs, dt_ms: i64, day: u32) {
        let frac = dt_ms as f64 / DAY_MS as f64;
        self.carry_raw += self.spec.raw_datasets_per_day as f64 * frac;
        while self.carry_raw >= 1.0 {
            self.carry_raw -= 1.0;
            self.produce_raw(ctx, now);
        }
        // Conference crunches surge both analysis reads and the derivation
        // production feeding them (paper §5.3: "weeks leading up to
        // physics conferences").
        let mult = match self.spec.burst {
            Some((s, e, m)) if day >= s && day < e => m,
            _ => 1.0,
        };
        self.carry_der += self.spec.derivations_per_day as f64 * frac * mult;
        while self.carry_der >= 1.0 {
            self.carry_der -= 1.0;
            self.derive(ctx, now);
        }
        self.carry_ana += self.spec.analysis_accesses_per_day as f64 * frac * mult;
        while self.carry_ana >= 1.0 {
            self.carry_ana -= 1.0;
            self.analyze(ctx, now);
        }
        // Discovery surges with analysis: users find data before reading
        // it (the conference-crunch listing storms of §5.3).
        self.carry_disc += self.spec.discovery_queries_per_day as f64 * frac * mult;
        while self.carry_disc >= 1.0 {
            self.carry_disc -= 1.0;
            self.discover(ctx);
        }
        if self.spec.multi_vo.is_some() {
            self.step_multi_vo(ctx, now, frac);
        }
    }

    /// Multi-VO tenant traffic: provision the population on first use,
    /// then drive Zipf-skewed per-tenant rule creation and auth churn.
    fn step_multi_vo(&mut self, ctx: &Ctx, now: EpochMs, frac: f64) {
        let mv = self.spec.multi_vo.clone().expect("checked by caller");
        if self.vo_accounts.is_empty() {
            self.provision_vos(ctx, &mv);
        }
        self.carry_vo_rules += mv.rules_per_day as f64 * frac;
        while self.carry_vo_rules >= 1.0 {
            self.carry_vo_rules -= 1.0;
            self.vo_rule(ctx, now, &mv);
        }
        self.carry_vo_logins += mv.logins_per_day as f64 * frac;
        while self.carry_vo_logins >= 1.0 {
            self.carry_vo_logins -= 1.0;
            self.vo_login(ctx, &mv);
        }
    }

    fn provision_vos(&mut self, ctx: &Ctx, mv: &MultiVoSpec) {
        let cat = &ctx.catalog;
        for vo in &mv.vos {
            for i in 0..mv.accounts_per_vo {
                let name = format!("{vo}{i:04}");
                if cat
                    .add_account_vo(&name, crate::core::types::AccountType::User, "", vo)
                    .is_err()
                {
                    continue; // already provisioned (recovered run)
                }
                let _ = cat.add_identity(
                    &name,
                    crate::core::types::AuthType::UserPass,
                    &name,
                    Some(&format!("pw-{name}")),
                );
                self.vo_accounts
                    .push((vo.clone(), name.clone(), format!("user.{name}")));
            }
        }
    }

    /// Zipf-pick a tenant account: the VO rank is heavy-tailed (first
    /// VOs dominate), the account within it uniform.
    fn pick_vo_account(&mut self, mv: &MultiVoSpec) -> Option<(String, String, String)> {
        if self.vo_accounts.is_empty() {
            return None;
        }
        let vo_rank = self.rng.zipf(mv.vos.len(), mv.zipf_theta);
        let start = vo_rank * mv.accounts_per_vo;
        let in_vo: Vec<&(String, String, String)> = self
            .vo_accounts
            .iter()
            .skip(start)
            .take(mv.accounts_per_vo)
            .collect();
        if in_vo.is_empty() {
            return Some(self.vo_accounts[0].clone());
        }
        Some(in_vo[self.rng.range_usize(0, in_vo.len())].clone())
    }

    /// One tenant replication: a file lands in the account's home scope
    /// at the T0 and a rule fans it to the T2s — per-VO usage, locks,
    /// and throttler traffic all attributed to the tenant.
    fn vo_rule(&mut self, ctx: &Ctx, now: EpochMs, mv: &MultiVoSpec) {
        let cat = &ctx.catalog;
        let Some((_vo, account, scope)) = self.pick_vo_account(mv) else { return };
        self.vo_files += 1;
        let fname = format!("user.f{:07}", self.vo_files);
        let bytes = (self.file_size() / 16).max(1);
        let adler = synthetic_adler32_for(&fname, bytes);
        if cat.add_file(&scope, &fname, &account, bytes, &adler, None).is_err() {
            return;
        }
        let key = DidKey::new(&scope, &fname);
        if let Ok(rep) = cat.add_replica("CERN-PROD", &key, ReplicaState::Available, None) {
            if let Some(sys) = ctx.fleet.get("CERN-PROD") {
                let _ = sys.put(&rep.pfn, bytes, now);
            }
        }
        let activity = if self.vo_files % 3 == 0 { "Production" } else { "Analysis" };
        let _ = cat.add_rule(
            RuleSpec::new(&account, key, "tier=2", 1)
                .with_lifetime(self.spec.aod_lifetime_ms)
                .with_activity(activity),
        );
    }

    /// One tenant login: issue a token via userpass and validate it —
    /// the auth hot path under churn (housekeeping purges the expiry
    /// backlog every virtual hour).
    fn vo_login(&mut self, ctx: &Ctx, mv: &MultiVoSpec) {
        let cat = &ctx.catalog;
        let Some((_vo, account, _scope)) = self.pick_vo_account(mv) else { return };
        if let Ok(token) = cat.auth_userpass(&account, &account, &format!("pw-{account}")) {
            let _ = cat.validate_token(&token.token);
        }
    }

    fn file_size(&mut self) -> u64 {
        self.rng.lognormal(self.spec.median_file_bytes as f64, 0.5) as u64
    }

    /// Detector output: a RAW dataset registered + uploaded at the Tier-0
    /// (paper §4.2: the Tier-0 facility populates storage, Rucio registers
    /// for later distribution). Subscriptions then archive it.
    fn produce_raw(&mut self, ctx: &Ctx, now: EpochMs) {
        let cat = &ctx.catalog;
        self.raw_count += 1;
        let ds_name = format!("raw.run{:06}", self.raw_count);
        if cat.add_dataset("data18", &ds_name, "tzero").is_err() {
            return;
        }
        let ds = DidKey::new("data18", &ds_name);
        let run = 358_000 + self.raw_count as i64;
        let stream = STREAMS[self.rng.range_usize(0, STREAMS.len())];
        let _ = cat.set_metadata_bulk(
            &ds,
            vec![
                ("datatype".into(), MetaValue::Str("RAW".into())),
                ("run".into(), MetaValue::Int(run)),
                ("project".into(), MetaValue::Str("data18".into())),
                ("stream".into(), MetaValue::Str(stream.into())),
            ],
        );
        let t0 = ctx.fleet.get("CERN-PROD");
        for i in 0..self.spec.files_per_dataset {
            let fname = format!("{ds_name}.f{i:04}");
            let bytes = self.file_size();
            let adler = synthetic_adler32_for(&fname, bytes);
            if cat.add_file("data18", &fname, "tzero", bytes, &adler, None).is_err() {
                continue;
            }
            let key = DidKey::new("data18", &fname);
            if let Ok(rep) = cat.add_replica("CERN-PROD", &key, ReplicaState::Available, None) {
                if let Some(sys) = &t0 {
                    let _ = sys.put(&rep.pfn, bytes, now);
                }
            }
            let _ = cat.attach(&ds, &key);
        }
        let _ = cat.close(&ds);
        // pin the fresh RAW at the T0 briefly (buffer semantics)
        let _ = cat.add_rule(
            RuleSpec::new("tzero", ds.clone(), "CERN-PROD", 1)
                .with_lifetime(7 * DAY_MS)
                .with_activity("T0 Export"),
        );
        self.raws.push((ds, run));
        if self.raws.len() > 200 {
            self.raws.remove(0);
        }
    }

    /// Derivation production: RAW → AOD, output registered where the T1
    /// processing ran, then consolidated to two T2s with a lifetime.
    fn derive(&mut self, ctx: &Ctx, now: EpochMs) {
        let cat = &ctx.catalog;
        if self.raws.is_empty() {
            return;
        }
        let (raw, run) = self.raws[self.rng.range_usize(0, self.raws.len())].clone();
        self.aod_count += 1;
        let ds_name = format!("aod.{:06}", self.aod_count);
        if cat.add_dataset("mc20", &ds_name, "prod").is_err() {
            return;
        }
        let ds = DidKey::new("mc20", &ds_name);
        let _ = cat.set_metadata_bulk(
            &ds,
            vec![
                ("datatype".into(), MetaValue::Str("AOD".into())),
                ("run".into(), MetaValue::Int(run)), // derivations inherit the run
                ("project".into(), MetaValue::Str("mc20".into())),
            ],
        );
        // processing site: the T1 disk of a random region
        let t1s = cat
            .resolve_rse_expression("tier=1&type=disk")
            .unwrap_or_default();
        if t1s.is_empty() {
            return;
        }
        let site = t1s[self.rng.range_usize(0, t1s.len())].clone();
        let n_files = (self.spec.files_per_dataset / 2).max(1);
        for i in 0..n_files {
            let fname = format!("{ds_name}.f{i:04}");
            let bytes = self.file_size() / 4; // AODs are smaller
            let adler = synthetic_adler32_for(&fname, bytes);
            if cat.add_file("mc20", &fname, "prod", bytes, &adler, None).is_err() {
                continue;
            }
            let key = DidKey::new("mc20", &fname);
            if let Ok(rep) = cat.add_replica(&site, &key, ReplicaState::Available, None) {
                if let Some(sys) = ctx.fleet.get(&site) {
                    let _ = sys.put(&rep.pfn, bytes, now);
                }
            }
            let _ = cat.attach(&ds, &key);
        }
        let _ = cat.close(&ds);
        // job input accounting: reading RAW (tape recall pressure occasionally)
        for f in cat.resolve_files(&raw).into_iter().take(2) {
            if let Some(rep) = cat.available_replicas(&f.key).first() {
                crate::daemons::tracer::emit_trace(
                    &ctx.broker,
                    now,
                    "get",
                    &rep.rse,
                    &f.key.scope,
                    &f.key.name,
                );
            }
        }
        // consolidation rule: 2 T2 copies with lifetime (deletion pressure)
        let _ = cat.add_rule(
            RuleSpec::new("prod", ds.clone(), "tier=2", 2)
                .with_lifetime(self.spec.aod_lifetime_ms)
                .with_activity("Production"),
        );
        self.aods.push(ds);
        if self.aods.len() > 500 {
            self.aods.remove(0);
        }
    }

    /// User analysis: Zipf-pick a recent AOD and download some files —
    /// traces feed popularity (→ C3PO + LRU deletion).
    fn analyze(&mut self, ctx: &Ctx, now: EpochMs) {
        let cat = &ctx.catalog;
        if self.aods.is_empty() {
            return;
        }
        // most recent = rank 0 (newest data is hottest)
        let rank = self.rng.zipf(self.aods.len(), 1.3);
        let idx = self.aods.len() - 1 - rank;
        let ds = self.aods[idx].clone();
        for f in cat.resolve_files(&ds).into_iter().take(3) {
            if let Some(rep) = cat.available_replicas(&f.key).first() {
                crate::daemons::tracer::emit_trace(
                    &ctx.broker,
                    now,
                    "download",
                    &rep.rse,
                    &f.key.scope,
                    &f.key.name,
                );
            }
        }
    }

    /// Data discovery: a user resolves a `meta-expr` filter against the
    /// namespace before reading — list-by-metadata is the dominant
    /// catalog read pattern once the namespace is large. Filters mix
    /// indexed equality, run-number ranges, and name globs so both
    /// planner paths stay hot under live mutation.
    fn discover(&mut self, ctx: &Ctx) {
        let cat = &ctx.catalog;
        let newest_run = 358_000 + self.raw_count as i64;
        let (scope, filter) = match self.rng.range_usize(0, 5) {
            0 => ("data18".to_string(), "datatype=RAW".to_string()),
            1 => {
                let stream = STREAMS[self.rng.range_usize(0, STREAMS.len())];
                ("data18".to_string(), format!("datatype=RAW AND stream={stream}"))
            }
            2 => {
                let lo = newest_run - self.rng.range_i64(1, 40);
                ("mc20".to_string(), format!("datatype=AOD AND run>={lo}"))
            }
            3 => {
                let run = 358_000 + self.rng.range_i64(1, (self.raw_count as i64).max(2));
                ("data18".to_string(), format!("run={run}"))
            }
            _ => ("mc20".to_string(), "name=aod.0* AND type=DATASET".to_string()),
        };
        let expr = metaexpr::parse(&filter).expect("workload filters are well-formed");
        let hits = cat.query_dids(&scope, &expr, false);
        cat.metrics.incr("discovery.queries", 1);
        cat.metrics.incr("discovery.hits", hits.len() as u64);
    }

    /// Occasional tape recall campaign (paper §5.3 tape numbers): request
    /// a disk copy of an old RAW dataset whose disk replicas are gone.
    pub fn recall_campaign(&mut self, ctx: &Ctx, _now: EpochMs) {
        let cat = &ctx.catalog;
        if self.raws.is_empty() {
            return;
        }
        let (raw, _run) = self.raws[self.rng.range_usize(0, self.raws.len() / 2 + 1)].clone();
        let _ = cat.add_rule(
            RuleSpec::new("prod", raw, "tier=1&type=disk", 1)
                .with_lifetime(7 * DAY_MS)
                .with_activity("Staging"),
        );
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.raw_count, self.aod_count)
    }
}

/// Hour-of-day activity modulation (diurnal shape for Fig 6).
pub fn diurnal_factor(now: EpochMs) -> f64 {
    let hour = ((now / HOUR_MS) % 24) as f64;
    1.0 + 0.3 * (2.0 * std::f64::consts::PI * (hour - 14.0) / 24.0).cos()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::clock::Clock;
    use crate::common::config::Config;
    use crate::sim::grid::{build_grid, GridSpec};

    #[test]
    fn raw_production_registers_and_uploads() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        let mut wl = Workload::new(WorkloadSpec::default());
        wl.produce_raw(&ctx, ctx.catalog.now());
        let (raws, _) = wl.stats();
        assert_eq!(raws, 1);
        let dids = ctx.catalog.list_dids("data18", Some("raw.*"), None, false);
        assert_eq!(dids.len(), 1 + WorkloadSpec::default().files_per_dataset);
        assert!(ctx.fleet.get("CERN-PROD").unwrap().file_count() > 0);
        // datasets carry typed metadata for the discovery engine
        let ds = &wl.raws[0].0;
        let meta = ctx.catalog.get_metadata(ds).unwrap();
        assert_eq!(meta["datatype"], MetaValue::Str("RAW".into()));
        assert_eq!(meta["run"], MetaValue::Int(358_001));
        assert!(meta.contains_key("stream"));
    }

    #[test]
    fn discovery_queries_run_through_the_planner() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        let mut wl = Workload::new(WorkloadSpec::default());
        for _ in 0..5 {
            wl.produce_raw(&ctx, 0);
            wl.derive(&ctx, 0);
        }
        for _ in 0..20 {
            wl.discover(&ctx);
        }
        let m = &ctx.catalog.metrics;
        assert_eq!(m.counter("discovery.queries"), 20);
        assert!(m.counter("discovery.hits") > 0, "filters find the produced data");
        assert!(
            m.counter("dids.query.indexed") > 0,
            "metadata filters hit the inverted index"
        );
        // an AOD run-range filter finds the derivations with inherited runs
        let expr = metaexpr::parse("datatype=AOD AND run>=358001").unwrap();
        assert_eq!(ctx.catalog.query_dids("mc20", &expr, false).len(), 5);
    }

    #[test]
    fn derivation_follows_raw() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        let mut wl = Workload::new(WorkloadSpec::default());
        wl.produce_raw(&ctx, 0);
        wl.derive(&ctx, 0);
        assert_eq!(wl.aods.len(), 1);
        // consolidation rule exists with 2 copies
        let rules = ctx.catalog.list_rules_for_did(&wl.aods[0]);
        assert_eq!(rules.len(), 1);
        assert_eq!(rules[0].copies, 2);
        assert!(rules[0].expires_at.is_some());
    }

    #[test]
    fn step_rates_integrate() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        let mut wl = Workload::new(WorkloadSpec {
            raw_datasets_per_day: 4,
            derivations_per_day: 2,
            analysis_accesses_per_day: 0,
            ..Default::default()
        });
        // a full day in 1h steps
        for h in 0..24 {
            wl.step(&ctx, h * HOUR_MS, HOUR_MS, 0);
        }
        let (raws, aods) = wl.stats();
        // carry accumulation is float-based: allow the off-by-one ulp case
        assert!((3..=4).contains(&raws), "raws={raws}");
        assert!((1..=2).contains(&aods), "aods={aods}");
    }

    #[test]
    fn multi_vo_population_generates_tenant_traffic() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        let mut wl = Workload::new(WorkloadSpec {
            raw_datasets_per_day: 0,
            derivations_per_day: 0,
            analysis_accesses_per_day: 0,
            discovery_queries_per_day: 0,
            multi_vo: Some(MultiVoSpec {
                vos: vec!["atlas".into(), "cms".into(), "belle".into()],
                accounts_per_vo: 40,
                rules_per_day: 240,
                logins_per_day: 120,
                zipf_theta: 1.1,
            }),
            ..Default::default()
        });
        for h in 0..24 {
            wl.step(&ctx, h * HOUR_MS, HOUR_MS, 0);
        }
        let cat = &ctx.catalog;
        assert_eq!(wl.vo_accounts.len(), 120, "3 VOs × 40 accounts");
        // the Zipf head dominates but the tail is present: usage shows
        // up attributed to more than one tenant
        let roll = cat.vo_usage();
        assert!(!roll.is_empty(), "tenant usage accumulated: {roll:?}");
        assert!(
            roll.keys().all(|vo| ["atlas", "cms", "belle"].contains(&vo.as_str())),
            "only tenant VOs in the rollup: {roll:?}"
        );
        assert!(cat.metrics.counter("auth.tokens_issued") > 0, "login churn ran");
        // tenant isolation + rollup invariants hold under the generator
        let v = crate::sim::invariants::check(cat);
        assert_eq!(v, Vec::new());
    }

    #[test]
    fn burst_multiplies_analysis() {
        let ctx = build_grid(&GridSpec::default(), Clock::sim_at(0), Config::new());
        let mut wl = Workload::new(WorkloadSpec {
            raw_datasets_per_day: 1,
            derivations_per_day: 1,
            analysis_accesses_per_day: 10,
            burst: Some((5, 6, 3.0)),
            ..Default::default()
        });
        wl.produce_raw(&ctx, 0);
        wl.derive(&ctx, 0);
        let traces_sub = ctx.broker.subscribe("traces", None);
        wl.step(&ctx, 0, DAY_MS, 0);
        let normal = ctx.broker.poll("traces", traces_sub, 100_000).len();
        wl.step(&ctx, DAY_MS, DAY_MS, 5);
        let burst = ctx.broker.poll("traces", traces_sub, 100_000).len();
        assert!(burst > normal * 2, "burst {burst} vs normal {normal}");
    }
}
