//! Storage-system simulator — the EOS/dCache/XrootD/StoRM/DPM/CASTOR
//! substitute (paper §1.3).
//!
//! Each [`StorageSystem`] models one site storage endpoint:
//! * **disk** — immediate reads/writes bounded by capacity;
//! * **tape** — asynchronous write buffer ("efficient packing of files on
//!   the magnetic bands") and staged reads through a robot queue with
//!   mount latency (paper §1.3: "clients will have to wait for the tape
//!   robot to stage the file");
//! * failure/corruption injection per-operation (drives suspicious/bad
//!   replica handling, STUCK rules, and the Fig 8 efficiency structure
//!   together with [`crate::netsim`]);
//! * storage dumps (the plain-text file lists "provided periodically by
//!   the storage administrators", §4.4) for the consistency auditor.
//!
//! Files are metadata records (size + checksum), not real bytes — except
//! that small files can carry real content for the end-user upload/download
//! paths in the examples. The *checksum* of a synthetic file is a
//! deterministic function of (pfn, size) so corruption is detectable
//! exactly like a real Adler-32 mismatch.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, RwLock};

use crate::common::checksum;
use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};

/// Kind of backend (paper §2.4 / §1.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageKind {
    Disk,
    Tape,
    /// Volatile cache: content may disappear outside Rucio's control
    /// (paper §2.4 "volatile" RSEs).
    Volatile,
}

/// A stored file record.
#[derive(Debug, Clone)]
pub struct StoredFile {
    pub pfn: String,
    pub bytes: u64,
    /// Adler-32 hex the storage will report for this file.
    pub adler32: String,
    /// Real content for small example files (None for synthetic files).
    pub content: Option<Vec<u8>>,
    pub created_at: EpochMs,
    /// Tape only: file is on a mounted/staged buffer and readable now.
    pub staged: bool,
}

/// Expected checksum of a synthetic (metadata-only) file, derived from
/// the *file name* (last path segment) + size so the same logical file has
/// the same checksum at every RSE, regardless of the lfn2pfn layout.
pub fn synthetic_adler32(pfn: &str, bytes: u64) -> String {
    let base = pfn.rsplit('/').next().unwrap_or(pfn);
    synthetic_adler32_for(base, bytes)
}

/// Checksum for a DID name directly (what the catalog registers).
pub fn synthetic_adler32_for(name: &str, bytes: u64) -> String {
    let seed = format!("{name}:{bytes}");
    checksum::adler32_hex(seed.as_bytes())
}

/// Per-operation failure knobs.
#[derive(Debug, Clone)]
pub struct FailurePolicy {
    /// Probability a write fails outright.
    pub write_fail: f64,
    /// Probability a read/stat fails ("storage configuration problems").
    pub read_fail: f64,
    /// Probability a write lands corrupted (checksum mismatch later).
    pub corrupt: f64,
    /// Probability a delete fails (the paper's deletion "error rate of 10
    /// to 20 million per month ... mostly ... authorisation").
    pub delete_fail: f64,
}

impl Default for FailurePolicy {
    fn default() -> Self {
        FailurePolicy { write_fail: 0.0, read_fail: 0.0, corrupt: 0.0, delete_fail: 0.0 }
    }
}

struct Inner {
    files: BTreeMap<String, StoredFile>,
    used: u64,
    staging_queue: Vec<(String, EpochMs)>, // (pfn, ready_at)
    rng_state: u64,
    // op counters for monitoring
    writes: u64,
    reads: u64,
    deletes: u64,
    failures: u64,
}

/// One simulated storage endpoint.
pub struct StorageSystem {
    pub name: String,
    pub kind: StorageKind,
    pub capacity: u64,
    /// Behind a lock so chaos scenarios can retune failure rates at
    /// runtime (corruption bursts, degraded endpoints).
    policy: RwLock<FailurePolicy>,
    /// Hard outage toggle: every storage operation fails while set
    /// (scenario engine; the files themselves survive the outage).
    offline: AtomicBool,
    /// Tape robot staging latency (ms) for a cold file.
    pub stage_latency_ms: i64,
    inner: Mutex<Inner>,
}

impl StorageSystem {
    pub fn new(name: &str, kind: StorageKind, capacity: u64) -> Self {
        StorageSystem {
            name: name.to_string(),
            kind,
            capacity,
            policy: RwLock::new(FailurePolicy::default()),
            offline: AtomicBool::new(false),
            stage_latency_ms: 4 * 60 * 1000, // 4 min robot mount+seek
            inner: Mutex::new(Inner {
                files: BTreeMap::new(),
                used: 0,
                staging_queue: Vec::new(),
                rng_state: 0x5EED,
                writes: 0,
                reads: 0,
                deletes: 0,
                failures: 0,
            }),
        }
    }

    pub fn with_policy(self, policy: FailurePolicy) -> Self {
        *self.policy.write().unwrap() = policy;
        self
    }

    /// Seed the failure-injection PRNG (determinism plumbing: the grid
    /// builder derives this from `GridSpec::seed`). `| 1` keeps the
    /// xorshift state non-zero.
    pub fn with_seed(self, seed: u64) -> Self {
        self.inner.lock().unwrap().rng_state = seed | 1;
        self
    }

    pub fn policy(&self) -> FailurePolicy {
        self.policy.read().unwrap().clone()
    }

    /// Swap the failure policy at runtime (chaos scenario engine).
    pub fn set_policy(&self, policy: FailurePolicy) {
        *self.policy.write().unwrap() = policy;
    }

    /// Take the whole endpoint down / bring it back. While offline every
    /// put/stat/get/stage/delete fails; out-of-band helpers (`vanish`,
    /// `plant_dark`, `corrupt`, `dump`) still work — the bits on disk do
    /// not disappear just because the service daemons are down.
    pub fn set_offline(&self, offline: bool) {
        self.offline.store(offline, Ordering::Relaxed);
    }

    pub fn is_offline(&self) -> bool {
        self.offline.load(Ordering::Relaxed)
    }

    fn offline_err(&self) -> RucioError {
        RucioError::StorageError(format!("{}: endpoint offline", self.name))
    }

    fn roll(inner: &mut Inner, p: f64) -> bool {
        // xorshift64* — deterministic per storage system.
        let mut x = inner.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        inner.rng_state = x;
        let u = (x.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64 / (1u64 << 53) as f64;
        u < p
    }

    /// Write a synthetic file (metadata only). Fails on capacity, policy,
    /// or duplicate pfn. Corruption silently stores a wrong checksum.
    pub fn put(&self, pfn: &str, bytes: u64, now: EpochMs) -> Result<()> {
        self.put_impl(pfn, bytes, None, now)
    }

    /// Write a real-content file (example/user paths).
    pub fn put_bytes(&self, pfn: &str, content: &[u8], now: EpochMs) -> Result<()> {
        self.put_impl(pfn, content.len() as u64, Some(content.to_vec()), now)
    }

    fn put_impl(&self, pfn: &str, bytes: u64, content: Option<Vec<u8>>, now: EpochMs) -> Result<()> {
        let policy = self.policy();
        let mut inner = self.inner.lock().unwrap();
        inner.writes += 1;
        if self.is_offline() {
            inner.failures += 1;
            return Err(self.offline_err());
        }
        if Self::roll(&mut inner, policy.write_fail) {
            inner.failures += 1;
            return Err(RucioError::StorageError(format!("{}: write failed", self.name)));
        }
        if inner.files.contains_key(pfn) {
            return Err(RucioError::Duplicate(format!("{}: pfn exists: {pfn}", self.name)));
        }
        if inner.used + bytes > self.capacity {
            inner.failures += 1;
            return Err(RucioError::NoSpaceLeft(self.name.clone()));
        }
        let mut adler = match &content {
            Some(c) => checksum::adler32_hex(c),
            None => synthetic_adler32(pfn, bytes),
        };
        if Self::roll(&mut inner, policy.corrupt) {
            // Corrupted write: stored checksum differs from the expected one.
            adler = checksum::adler32_hex(format!("CORRUPT:{pfn}").as_bytes());
        }
        let staged = self.kind != StorageKind::Tape; // tape files start cold
        inner.used += bytes;
        inner.files.insert(
            pfn.to_string(),
            StoredFile {
                pfn: pfn.to_string(),
                bytes,
                adler32: adler,
                content,
                created_at: now,
                staged,
            },
        );
        Ok(())
    }

    /// stat(): existence + size + checksum, honoring read-failure policy.
    pub fn stat(&self, pfn: &str) -> Result<StoredFile> {
        let policy = self.policy();
        let mut inner = self.inner.lock().unwrap();
        inner.reads += 1;
        if self.is_offline() {
            inner.failures += 1;
            return Err(self.offline_err());
        }
        if Self::roll(&mut inner, policy.read_fail) {
            inner.failures += 1;
            return Err(RucioError::StorageError(format!("{}: read failed", self.name)));
        }
        inner
            .files
            .get(pfn)
            .cloned()
            .ok_or_else(|| RucioError::SourceNotFound(format!("{}:{pfn}", self.name)))
    }

    /// Read for transfer/download. Tape requires the file to be staged.
    pub fn get(&self, pfn: &str) -> Result<StoredFile> {
        let f = self.stat(pfn)?;
        if self.kind == StorageKind::Tape && !f.staged {
            return Err(RucioError::StorageError(format!(
                "{}: {pfn} not staged (tape cold read)",
                self.name
            )));
        }
        Ok(f)
    }

    /// Request staging of a tape file; readable after the robot latency.
    pub fn stage(&self, pfn: &str, now: EpochMs) -> Result<EpochMs> {
        if self.kind != StorageKind::Tape {
            return Ok(now);
        }
        let mut inner = self.inner.lock().unwrap();
        if self.is_offline() {
            inner.failures += 1;
            return Err(self.offline_err());
        }
        if !inner.files.contains_key(pfn) {
            return Err(RucioError::SourceNotFound(format!("{}:{pfn}", self.name)));
        }
        // Queue depth adds linear delay (robot contention).
        let ready = now + self.stage_latency_ms + (inner.staging_queue.len() as i64) * 30_000;
        inner.staging_queue.push((pfn.to_string(), ready));
        Ok(ready)
    }

    /// Batch staging (tape-carousel waves): queue many recalls under one
    /// robot pass. Returns `(pfn, ready_at)` for every file accepted —
    /// unknown pfns and already-staged files are skipped rather than
    /// failing the wave. Queue contention accumulates across the batch
    /// exactly as per-file [`StorageSystem::stage`] calls would, so a
    /// deep wave pays the same linear robot delay.
    pub fn stage_batch(&self, pfns: &[String], now: EpochMs) -> Vec<(String, EpochMs)> {
        if self.kind != StorageKind::Tape {
            return pfns.iter().map(|p| (p.clone(), now)).collect();
        }
        let mut inner = self.inner.lock().unwrap();
        if self.is_offline() {
            inner.failures += 1;
            return Vec::new();
        }
        let mut out = Vec::with_capacity(pfns.len());
        for pfn in pfns {
            match inner.files.get(pfn) {
                Some(f) if f.staged => out.push((pfn.clone(), now)),
                Some(_) => {
                    let ready =
                        now + self.stage_latency_ms + (inner.staging_queue.len() as i64) * 30_000;
                    inner.staging_queue.push((pfn.clone(), ready));
                    out.push((pfn.clone(), ready));
                }
                None => {}
            }
        }
        out
    }

    /// Outstanding recall queue depth (files staged but not yet ready) —
    /// the tape-carousel wave-depth signal.
    pub fn staging_depth(&self) -> usize {
        self.inner.lock().unwrap().staging_queue.len()
    }

    /// Advance staging: mark files whose ready time has passed as staged.
    pub fn tick(&self, now: EpochMs) {
        let mut inner = self.inner.lock().unwrap();
        let due: Vec<String> = inner
            .staging_queue
            .iter()
            .filter(|(_, t)| *t <= now)
            .map(|(p, _)| p.clone())
            .collect();
        inner.staging_queue.retain(|(_, t)| *t > now);
        for pfn in due {
            if let Some(f) = inner.files.get_mut(&pfn) {
                f.staged = true;
            }
        }
    }

    pub fn delete(&self, pfn: &str) -> Result<()> {
        let policy = self.policy();
        let mut inner = self.inner.lock().unwrap();
        inner.deletes += 1;
        if self.is_offline() {
            inner.failures += 1;
            return Err(self.offline_err());
        }
        if Self::roll(&mut inner, policy.delete_fail) {
            inner.failures += 1;
            return Err(RucioError::StorageError(format!("{}: delete denied", self.name)));
        }
        match inner.files.remove(pfn) {
            Some(f) => {
                inner.used -= f.bytes;
                Ok(())
            }
            None => Err(RucioError::SourceNotFound(format!("{}:{pfn}", self.name))),
        }
    }

    /// Out-of-band removal (volatile caches, dark-file injection in tests):
    /// removes without going through the delete policy.
    pub fn vanish(&self, pfn: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.files.remove(pfn) {
            Some(f) => {
                inner.used -= f.bytes;
                true
            }
            None => false,
        }
    }

    /// Out-of-band write (dark files: "must have been put on Rucio-managed
    /// storage areas through unsupported methods", §4.4).
    pub fn plant_dark(&self, pfn: &str, bytes: u64, now: EpochMs) {
        let mut inner = self.inner.lock().unwrap();
        let adler = synthetic_adler32(pfn, bytes);
        inner.used += bytes;
        inner.files.insert(
            pfn.to_string(),
            StoredFile {
                pfn: pfn.to_string(),
                bytes,
                adler32: adler,
                content: None,
                created_at: now,
                staged: true,
            },
        );
    }

    /// Corrupt an existing file in place (bit rot injection).
    pub fn corrupt(&self, pfn: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.files.get_mut(pfn) {
            Some(f) => {
                f.adler32 = checksum::adler32_hex(format!("BITROT:{pfn}").as_bytes());
                f.content = None;
                true
            }
            None => false,
        }
    }

    pub fn used(&self) -> u64 {
        self.inner.lock().unwrap().used
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used())
    }

    pub fn file_count(&self) -> usize {
        self.inner.lock().unwrap().files.len()
    }

    /// The periodic storage dump for the consistency auditor (§4.4): all
    /// pfns with sizes, as of "now".
    pub fn dump(&self) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .files
            .values()
            .map(|f| (f.pfn.clone(), f.bytes))
            .collect()
    }

    /// (writes, reads, deletes, failures) counters.
    pub fn op_counters(&self) -> (u64, u64, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.writes, inner.reads, inner.deletes, inner.failures)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disk_put_get_delete_cycle() {
        let s = StorageSystem::new("DISK1", StorageKind::Disk, 1000);
        s.put("/a/f1", 400, 0).unwrap();
        assert_eq!(s.used(), 400);
        let f = s.get("/a/f1").unwrap();
        assert_eq!(f.bytes, 400);
        assert_eq!(f.adler32, synthetic_adler32("/a/f1", 400));
        s.delete("/a/f1").unwrap();
        assert_eq!(s.used(), 0);
        assert!(s.get("/a/f1").is_err());
    }

    #[test]
    fn capacity_enforced() {
        let s = StorageSystem::new("SMALL", StorageKind::Disk, 100);
        s.put("/f1", 60, 0).unwrap();
        assert!(matches!(s.put("/f2", 60, 0), Err(RucioError::NoSpaceLeft(_))));
        s.put("/f3", 40, 0).unwrap();
        assert_eq!(s.free(), 0);
    }

    #[test]
    fn duplicate_pfn_rejected() {
        let s = StorageSystem::new("D", StorageKind::Disk, 1000);
        s.put("/f", 10, 0).unwrap();
        assert!(matches!(s.put("/f", 10, 0), Err(RucioError::Duplicate(_))));
    }

    #[test]
    fn real_content_checksum() {
        let s = StorageSystem::new("D", StorageKind::Disk, 1000);
        s.put_bytes("/real", b"hello world", 0).unwrap();
        let f = s.get("/real").unwrap();
        assert_eq!(f.adler32, checksum::adler32_hex(b"hello world"));
        assert_eq!(f.content.as_deref(), Some(b"hello world".as_ref()));
    }

    #[test]
    fn tape_requires_staging() {
        let s = StorageSystem::new("TAPE", StorageKind::Tape, 10_000);
        s.put("/t/f1", 100, 0).unwrap();
        assert!(s.get("/t/f1").is_err(), "cold tape read must fail");
        let ready = s.stage("/t/f1", 1000).unwrap();
        assert!(ready > 1000);
        s.tick(ready - 1);
        assert!(s.get("/t/f1").is_err(), "not ready yet");
        s.tick(ready);
        assert!(s.get("/t/f1").is_ok(), "staged read works");
    }

    #[test]
    fn stage_batch_queues_wave_with_contention() {
        let s = StorageSystem::new("TAPE", StorageKind::Tape, 10_000);
        for i in 0..4 {
            s.put(&format!("/t/w{i}"), 100, 0).unwrap();
        }
        // one file already warm: batch must not re-queue it
        let warm = s.stage("/t/w0", 0).unwrap();
        s.tick(warm);
        let wave: Vec<String> = (0..4).map(|i| format!("/t/w{i}")).collect();
        let mut batch = wave.clone();
        batch.push("/t/ghost".into()); // unknown pfn skipped, not fatal
        let ready = s.stage_batch(&batch, 1000);
        assert_eq!(ready.len(), 4, "ghost skipped, four known files accepted");
        assert_eq!(ready[0], ("/t/w0".into(), 1000), "warm file ready immediately");
        // robot contention accumulates linearly across the cold tail
        let cold: Vec<i64> = ready[1..].iter().map(|(_, t)| *t).collect();
        assert!(cold.windows(2).all(|w| w[1] == w[0] + 30_000), "{cold:?}");
        assert_eq!(s.staging_depth(), 3);
        let last = *cold.last().unwrap();
        s.tick(last);
        assert_eq!(s.staging_depth(), 0);
        for p in &wave {
            assert!(s.get(p).is_ok(), "{p} staged after the wave drains");
        }
    }

    #[test]
    fn staging_queue_adds_contention_delay() {
        let s = StorageSystem::new("TAPE", StorageKind::Tape, 10_000);
        s.put("/t/a", 1, 0).unwrap();
        s.put("/t/b", 1, 0).unwrap();
        let r1 = s.stage("/t/a", 0).unwrap();
        let r2 = s.stage("/t/b", 0).unwrap();
        assert!(r2 > r1);
    }

    #[test]
    fn failure_policy_fires() {
        let s = StorageSystem::new("FLAKY", StorageKind::Disk, u64::MAX)
            .with_policy(FailurePolicy { write_fail: 0.5, ..Default::default() });
        let mut fails = 0;
        for i in 0..200 {
            if s.put(&format!("/f{i}"), 1, 0).is_err() {
                fails += 1;
            }
        }
        assert!((60..140).contains(&fails), "fails={fails}");
        let (_, _, _, failures) = s.op_counters();
        assert_eq!(failures as usize, fails);
    }

    #[test]
    fn corruption_changes_checksum() {
        let s = StorageSystem::new("D", StorageKind::Disk, 1000);
        s.put("/f", 10, 0).unwrap();
        assert!(s.corrupt("/f"));
        let f = s.get("/f").unwrap();
        assert_ne!(f.adler32, synthetic_adler32("/f", 10));
    }

    #[test]
    fn offline_endpoint_fails_everything_but_survives() {
        let s = StorageSystem::new("OUT", StorageKind::Disk, 1000);
        s.put("/f", 10, 0).unwrap();
        s.set_offline(true);
        assert!(s.is_offline());
        assert!(s.put("/g", 10, 0).is_err());
        assert!(s.stat("/f").is_err());
        assert!(s.delete("/f").is_err());
        assert_eq!(s.dump().len(), 1, "bits survive the outage");
        let (_, _, _, failures) = s.op_counters();
        assert!(failures >= 3);
        s.set_offline(false);
        assert_eq!(s.stat("/f").unwrap().bytes, 10);
    }

    #[test]
    fn runtime_policy_swap_takes_effect() {
        let s = StorageSystem::new("HOT", StorageKind::Disk, u64::MAX);
        s.put("/a", 1, 0).unwrap();
        s.set_policy(FailurePolicy { write_fail: 1.0, ..Default::default() });
        assert!(s.put("/b", 1, 0).is_err());
        s.set_policy(FailurePolicy::default());
        s.put("/b", 1, 0).unwrap();
        assert_eq!(s.policy().write_fail, 0.0);
    }

    #[test]
    fn seeded_rng_reproduces_failures() {
        let run = |seed: u64| -> Vec<bool> {
            let s = StorageSystem::new("SEEDED", StorageKind::Disk, u64::MAX)
                .with_policy(FailurePolicy { write_fail: 0.5, ..Default::default() })
                .with_seed(seed);
            (0..50).map(|i| s.put(&format!("/f{i}"), 1, 0).is_ok()).collect()
        };
        assert_eq!(run(99), run(99));
        assert_ne!(run(99), run(100));
    }

    #[test]
    fn dark_and_vanish_bypass_policy() {
        let s = StorageSystem::new("D", StorageKind::Disk, 1000);
        s.put("/known", 10, 0).unwrap();
        s.plant_dark("/dark", 20, 0);
        assert_eq!(s.file_count(), 2);
        let dump = s.dump();
        assert_eq!(dump.len(), 2);
        assert!(s.vanish("/known"));
        assert!(!s.vanish("/known"));
        assert_eq!(s.used(), 20);
    }

    #[test]
    fn corrupt_write_policy_mismatches_expected() {
        let s = StorageSystem::new("ROT", StorageKind::Disk, u64::MAX)
            .with_policy(FailurePolicy { corrupt: 1.0, ..Default::default() });
        s.put("/f", 10, 0).unwrap();
        let f = s.stat("/f").unwrap();
        assert_ne!(f.adler32, synthetic_adler32("/f", 10));
    }
}

/// A registry of all storage endpoints, keyed by RSE name. Shared by the
/// FTS simulator, the reaper, the auditor, and the client upload/download
/// paths.
#[derive(Default)]
pub struct Fleet {
    systems: std::sync::RwLock<BTreeMap<String, std::sync::Arc<StorageSystem>>>,
}

impl Fleet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, system: StorageSystem) -> std::sync::Arc<StorageSystem> {
        let arc = std::sync::Arc::new(system);
        self.systems
            .write()
            .unwrap()
            .insert(arc.name.clone(), arc.clone());
        arc
    }

    pub fn get(&self, rse: &str) -> Option<std::sync::Arc<StorageSystem>> {
        self.systems.read().unwrap().get(rse).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        self.systems.read().unwrap().keys().cloned().collect()
    }

    /// Advance tape staging queues everywhere.
    pub fn tick(&self, now: EpochMs) {
        for s in self.systems.read().unwrap().values() {
            s.tick(now);
        }
    }

    /// Total outstanding recall depth across every tape endpoint (the
    /// carousel wave-depth curve).
    pub fn staging_depth(&self) -> usize {
        self.systems
            .read()
            .unwrap()
            .values()
            .filter(|s| s.kind == StorageKind::Tape)
            .map(|s| s.staging_depth())
            .sum()
    }
}

#[cfg(test)]
mod fleet_tests {
    use super::*;

    #[test]
    fn fleet_registers_and_resolves() {
        let fleet = Fleet::new();
        fleet.add(StorageSystem::new("A-DISK", StorageKind::Disk, 100));
        fleet.add(StorageSystem::new("B-TAPE", StorageKind::Tape, 100));
        assert!(fleet.get("A-DISK").is_some());
        assert!(fleet.get("NOPE").is_none());
        assert_eq!(fleet.names(), vec!["A-DISK", "B-TAPE"]);
    }
}
