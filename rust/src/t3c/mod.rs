//! T³C — Transfer-Time-To-Complete prediction (paper §6.3): "a trace
//! record is created for every single transfer ... it is possible to
//! apply large-scale statistical analysis techniques ... and thus predict
//! the characteristics of large-scale data movement"; "when a user
//! creates a new rule, Rucio will reply with an estimate of when the rule
//! will be finished".
//!
//! This module is the extension point the paper describes, with three
//! simultaneous models ("the module allows use of simultaneous models and
//! features the ability to easily compare their performance"):
//! * the **MLP** — AOT-compiled Pallas kernels, trained *online* in Rust
//!   by executing the `t3c_train_step` artifact on completed-transfer
//!   telemetry (fwd/bwd lives in the JAX artifact);
//! * a **linear** online-SGD baseline (pure Rust);
//! * a **naive** running-mean baseline.
//!
//! Targets are log-seconds (durations span 5 orders of magnitude).

use crate::common::clock::EpochMs;
use crate::common::units::GB;
use crate::core::types::{RequestState, TransferRequest};
use crate::mq::SubId;
use crate::runtime::{ref_t3c_predict, Runtime, T3cParams};

use crate::daemons::{Ctx, Daemon};

pub const N_FEATURES: usize = 8;

/// One training sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub x: [f32; N_FEATURES],
    /// ln(duration seconds + 1)
    pub y: f32,
}

/// Feature extraction shared by training and prediction.
/// [log10 bytes, distance rank, queued on dst, observed link bw,
///  src-is-tape, activity priority, fraction-of-day, bias]
pub fn features(
    ctx: &Ctx,
    bytes: u64,
    src_rse: Option<&str>,
    dst_rse: &str,
    activity: &str,
    now: EpochMs,
) -> [f32; N_FEATURES] {
    let cat = &ctx.catalog;
    let log_bytes = ((bytes.max(1)) as f32).log10() / 12.0; // ~[0,1] up to TB
    let (dist, bw, tape) = match src_rse {
        Some(src) => {
            let d = cat.distance(src, dst_rse).unwrap_or(6) as f32 / 6.0;
            let (s_site, d_site) = (
                cat.get_rse(src).map(|r| r.site().to_string()).unwrap_or_default(),
                cat.get_rse(dst_rse).map(|r| r.site().to_string()).unwrap_or_default(),
            );
            let bw = ctx
                .net
                .observed_bps(&s_site, &d_site)
                .map(|b| (b as f32 / GB as f32).min(4.0))
                .unwrap_or(0.0);
            let tape = cat.get_rse(src).map(|r| r.is_tape).unwrap_or(false);
            (d, bw, if tape { 1.0 } else { 0.0 })
        }
        None => (1.0, 0.0, 0.0),
    };
    // Waiting counts as pressure too: an admission-held backlog on the
    // destination is congestion the predictor must see.
    let queued = [RequestState::Waiting, RequestState::Queued]
        .iter()
        .flat_map(|s| cat.requests_by_state.get(s))
        .filter_map(|id| cat.requests.get(&id))
        .filter(|r| r.dst_rse == dst_rse)
        .count() as f32;
    let act_prio = match activity {
        "T0 Export" => 1.0,
        "Production" => 0.7,
        "Data Rebalancing" => 0.2,
        _ => 0.5,
    };
    let day_frac = ((now / 1000) % 86_400) as f32 / 86_400.0;
    [
        log_bytes,
        dist,
        (queued / 100.0).min(4.0),
        bw,
        tape,
        act_prio,
        day_frac,
        1.0,
    ]
}

/// Naive baseline: running mean of log-durations.
#[derive(Debug, Default, Clone)]
pub struct NaiveModel {
    sum: f64,
    n: u64,
}

impl NaiveModel {
    pub fn train(&mut self, s: &Sample) {
        self.sum += s.y as f64;
        self.n += 1;
    }

    pub fn predict(&self, _x: &[f32; N_FEATURES]) -> f32 {
        if self.n == 0 {
            5.0
        } else {
            (self.sum / self.n as f64) as f32
        }
    }
}

/// Linear online-SGD baseline.
#[derive(Debug, Clone)]
pub struct LinearModel {
    pub w: [f32; N_FEATURES],
    pub lr: f32,
}

impl Default for LinearModel {
    fn default() -> Self {
        LinearModel { w: [0.0; N_FEATURES], lr: 0.02 }
    }
}

impl LinearModel {
    pub fn predict(&self, x: &[f32; N_FEATURES]) -> f32 {
        x.iter().zip(&self.w).map(|(a, b)| a * b).sum()
    }

    pub fn train(&mut self, s: &Sample) {
        let err = self.predict(&s.x) - s.y;
        for i in 0..N_FEATURES {
            self.w[i] -= self.lr * err * s.x[i];
        }
    }
}

/// The MLP model: PJRT-executed forward + online train step. Falls back
/// to the pure-Rust forward when artifacts are unavailable (no training
/// then — documented degradation).
pub struct MlpModel {
    pub runtime: Option<Runtime>,
    pub params: T3cParams,
    pub lr: f32,
    pub steps: u64,
    pub last_loss: f32,
    pub loss_history: Vec<f32>,
}

impl MlpModel {
    pub fn load_default() -> Self {
        match Runtime::load_default() {
            Ok(rt) => {
                let params =
                    T3cParams::load(&rt.dir, rt.manifest.n_features, rt.manifest.t3c_hidden)
                        .expect("artifacts present but t3c_params.bin unreadable");
                MlpModel {
                    runtime: Some(rt),
                    params,
                    lr: 0.02,
                    steps: 0,
                    last_loss: f32::NAN,
                    loss_history: Vec::new(),
                }
            }
            Err(_) => MlpModel {
                runtime: None,
                params: T3cParams {
                    w1: vec![0.01; N_FEATURES * 32],
                    b1: vec![0.0; 32],
                    w2: vec![0.01; 32],
                    b2: vec![0.0; 1],
                    d: N_FEATURES,
                    h: 32,
                },
                lr: 0.02,
                steps: 0,
                last_loss: f32::NAN,
                loss_history: Vec::new(),
            },
        }
    }

    pub fn predict(&self, x: &[f32; N_FEATURES]) -> f32 {
        match &self.runtime {
            Some(rt) => rt
                .t3c_predict(&self.params, x, 1)
                .map(|v| v[0])
                .unwrap_or_else(|_| ref_t3c_predict(&self.params, x, 1)[0]),
            None => ref_t3c_predict(&self.params, x, 1)[0],
        }
    }

    /// Train on a batch (≤ artifact batch size). Returns the loss.
    pub fn train_batch(&mut self, batch: &[Sample]) -> Option<f32> {
        let rt = self.runtime.as_ref()?;
        let rows = batch.len().min(rt.manifest.t3c_batch);
        if rows == 0 {
            return None;
        }
        let mut x = vec![0f32; rows * N_FEATURES];
        let mut y = vec![0f32; rows];
        for (i, s) in batch.iter().take(rows).enumerate() {
            x[i * N_FEATURES..(i + 1) * N_FEATURES].copy_from_slice(&s.x);
            y[i] = s.y;
        }
        match rt.t3c_train_step(&self.params, &x, &y, rows, self.lr) {
            Ok((loss, params)) => {
                self.params = params;
                self.steps += 1;
                self.last_loss = loss;
                self.loss_history.push(loss);
                Some(loss)
            }
            Err(e) => {
                crate::log_warn!("t3c train step failed: {e}");
                None
            }
        }
    }
}

/// The T³C daemon: harvests completed-transfer telemetry from the broker
/// and trains all three models online.
pub struct T3c {
    pub ctx: Ctx,
    sub: SubId,
    buffer: Vec<Sample>,
    pub mlp: MlpModel,
    pub linear: LinearModel,
    pub naive: NaiveModel,
    pub samples_seen: u64,
}

impl T3c {
    pub fn new(ctx: Ctx) -> Self {
        let sub = ctx.broker.subscribe("transfer.fts", Some("transfer-done"));
        T3c {
            ctx,
            sub,
            buffer: Vec::new(),
            mlp: MlpModel::load_default(),
            linear: LinearModel::default(),
            naive: NaiveModel::default(),
            samples_seen: 0,
        }
    }

    /// Build a sample from a completion event payload.
    fn sample_from_event(&self, payload: &crate::jsonx::Json) -> Option<Sample> {
        let bytes = payload.opt_u64("bytes")?;
        let submitted = payload.opt_i64("submitted_at")?;
        let finished = payload.opt_i64("finished_at")?;
        let src = payload.opt_str("src_rse")?;
        let dst = payload.opt_str("dst_rse")?;
        let activity = payload.opt_str("activity").unwrap_or("User Subscriptions");
        let dur_s = ((finished - submitted).max(1) as f32) / 1000.0;
        Some(Sample {
            x: features(&self.ctx, bytes, Some(src), dst, activity, finished),
            y: (dur_s + 1.0).ln(),
        })
    }

    /// Predicted seconds-to-complete for a queued request.
    pub fn predict_request(&self, req: &TransferRequest, now: EpochMs) -> f32 {
        let x = features(
            &self.ctx,
            req.bytes,
            req.src_rse.as_deref(),
            &req.dst_rse,
            &req.activity,
            now,
        );
        (self.mlp.predict(&x).exp() - 1.0).max(0.0)
    }

    /// Rule ETA (paper: "Rucio will reply with an estimate of when the
    /// rule will be finished ... calculations across all potential file
    /// transfers"): max predicted completion over pending requests.
    pub fn estimate_rule_eta(&self, rule_id: u64, now: EpochMs) -> Option<f32> {
        let cat = &self.ctx.catalog;
        let pending: Vec<TransferRequest> = cat
            .requests
            .scan(|r| {
                r.rule_id == rule_id
                    && matches!(
                        r.state,
                        RequestState::Waiting
                            | RequestState::Queued
                            | RequestState::Submitted
                            | RequestState::Retry
                    )
            })
            .into_iter()
            .collect();
        if pending.is_empty() {
            return None;
        }
        pending
            .iter()
            .map(|r| self.predict_request(r, now))
            .max_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

impl Daemon for T3c {
    fn name(&self) -> &'static str {
        "t3c"
    }

    fn interval_ms(&self) -> i64 {
        30_000
    }

    fn tick(&mut self, _now: EpochMs) -> usize {
        let mut harvested = 0;
        loop {
            let msgs = self.ctx.broker.poll("transfer.fts", self.sub, 500);
            if msgs.is_empty() {
                break;
            }
            for m in msgs {
                if let Some(s) = self.sample_from_event(&m.payload) {
                    self.naive.train(&s);
                    self.linear.train(&s);
                    self.buffer.push(s);
                    self.samples_seen += 1;
                    harvested += 1;
                }
            }
        }
        // Train the MLP in artifact-sized batches.
        let batch_size = self
            .mlp
            .runtime
            .as_ref()
            .map(|r| r.manifest.t3c_batch)
            .unwrap_or(32);
        while self.buffer.len() >= batch_size {
            let batch: Vec<Sample> = self.buffer.drain(..batch_size).collect();
            self.mlp.train_batch(&batch);
        }
        self.ctx
            .catalog
            .metrics
            .gauge_set("t3c.samples", self.samples_seen);
        harvested
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonx::Json;
    use crate::daemons::conveyor::tests::rig;

    fn event(bytes: u64, dur_ms: i64) -> Json {
        Json::obj()
            .with("bytes", bytes)
            .with("src_rse", "SRC-DISK")
            .with("dst_rse", "DST-A")
            .with("activity", "Production")
            .with("submitted_at", 0i64)
            .with("finished_at", dur_ms)
    }

    #[test]
    fn harvests_events_and_trains_baselines() {
        let (ctx, _cat) = rig();
        let mut t3c = T3c::new(ctx.clone());
        for i in 0..10 {
            ctx.broker.publish(
                "transfer.fts",
                crate::mq::Message::new("transfer-done", event(1_000_000, 5_000 + i), 0),
            );
        }
        // failures are filtered by the subscription
        ctx.broker.publish(
            "transfer.fts",
            crate::mq::Message::new("transfer-failed", event(1, 1), 0),
        );
        let n = t3c.tick(0);
        assert_eq!(n, 10);
        // naive model learned ~ln(6)
        let x = features(&ctx, 1_000_000, Some("SRC-DISK"), "DST-A", "Production", 0);
        let naive = t3c.naive.predict(&x);
        assert!((naive - (6.0f32).ln()).abs() < 0.3, "naive={naive}");
    }

    #[test]
    fn linear_model_learns_size_dependence() {
        let (ctx, _cat) = rig();
        let mut lin = LinearModel::default();
        // duration proportional to bytes → log-duration correlates with
        // log-bytes (feature 0)
        for i in 0..2000 {
            let bytes = 1_000_000u64 * ((i % 100) + 1);
            let dur_s = bytes as f32 / 1e6;
            let x = features(&ctx, bytes, Some("SRC-DISK"), "DST-A", "Production", 0);
            lin.train(&Sample { x, y: (dur_s + 1.0).ln() });
        }
        let small = features(&ctx, 1_000_000, Some("SRC-DISK"), "DST-A", "Production", 0);
        let big = features(&ctx, 100_000_000, Some("SRC-DISK"), "DST-A", "Production", 0);
        assert!(lin.predict(&big) > lin.predict(&small), "bigger transfers take longer");
    }

    #[test]
    fn mlp_online_training_improves_over_naive() {
        if !crate::runtime::artifacts_available() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let (ctx, _cat) = rig();
        let mut t3c = T3c::new(ctx.clone());
        assert!(t3c.mlp.runtime.is_some());
        // synthetic workload: duration driven by bytes
        let mut seed = 99u64;
        for _ in 0..20 {
            for _ in 0..32 {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(7);
                let bytes = 1_000_000 + (seed >> 40);
                let dur_ms = (bytes / 1000) as i64;
                ctx.broker.publish(
                    "transfer.fts",
                    crate::mq::Message::new("transfer-done", event(bytes, dur_ms), 0),
                );
            }
            t3c.tick(0);
        }
        assert!(t3c.mlp.steps >= 10, "trained {} steps", t3c.mlp.steps);
        let h = &t3c.mlp.loss_history;
        assert!(
            h.last().unwrap() < h.first().unwrap(),
            "loss did not fall: {h:?}"
        );
    }

    #[test]
    fn rule_eta_covers_pending_requests() {
        let (ctx, cat) = rig();
        use crate::daemons::conveyor::tests::seed_file;
        let f = seed_file(&ctx, "eta", 1_000_000);
        let rid = cat
            .add_rule(crate::core::rules_api::RuleSpec::new("root", f, "DST-A", 1))
            .unwrap();
        let t3c = T3c::new(ctx.clone());
        let eta = t3c.estimate_rule_eta(rid, cat.now());
        assert!(eta.is_some());
        assert!(eta.unwrap() >= 0.0);
        // satisfied rule → no pending requests → no ETA
        let req = cat.requests.scan(|_| true)[0].clone();
        cat.on_transfer_done(req.id).unwrap();
        assert!(t3c.estimate_rule_eta(rid, cat.now()).is_none());
    }
}
