use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::placement::{C3po, RefScorer};
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;
use rucio::daemons::Daemon;

#[test]
fn c3po_places_under_driver_workload() {
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 2, ..Default::default() },
        WorkloadSpec { analysis_accesses_per_day: 400, ..Default::default() },
        Config::new(),
    );
    let ctx = driver.ctx.clone();
    let mut c3po = C3po::new(ctx.clone(), Box::new(RefScorer));
    let mut placed = 0;
    for day in 0..6 {
        driver.run_days(1, 10 * MINUTE_MS);
        // debug: how many popularity rows are hot datasets?
        let mut hot = 0;
        let mut ds_pop = 0;
        ctx.catalog.popularity.for_each(|p| {
            if let Ok(d) = ctx.catalog.get_did(&p.did) {
                if d.did_type == rucio::core::types::DidType::Dataset {
                    ds_pop += 1;
                    if p.window_accesses >= 3 { hot += 1; }
                }
            }
        });
        let n = c3po.tick(ctx.catalog.now());
        placed += n;
        eprintln!("day {day}: ds_pop={ds_pop} hot={hot} placed_now={n} decisions={}", c3po.decisions.len());
    }
    eprintln!("total placed {placed}");
    assert!(placed > 0);
}
