//! Campaign-scale scenario pack (standing e2e suite): the declarative
//! campaign engine runs a reprocessing, a mass deletion, and a tape
//! carousel against a live grid with the full invariant suite on a
//! cadence, and
//!
//! * a fixed seed makes the whole season bit-for-bit reproducible — two
//!   runs produce *identical* campaign reports;
//! * invariants stay clean at every checkpoint of every campaign;
//! * the carousel's recall waves never drive any FTS link above its
//!   per-link cap, and the batched stage-in queue is actually exercised;
//! * a mass-deletion campaign over a non-greedy (cache) RSE respects the
//!   free-space watermark mid-sweep and evicts in LRU order when the
//!   only popularity signal is read traces.

use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::core::rse::Rse;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState, RuleState};
use rucio::daemons::tracer::emit_trace;
use rucio::daemons::Ctx;
use rucio::sim::campaign::{run_campaign, run_season, CampaignSpec};
use rucio::sim::driver::{standard_driver, Driver};
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;
use rucio::storagesim::{synthetic_adler32_for, StorageKind, StorageSystem};

// ---------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------

fn build_driver(seed: u64) -> Driver {
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "1h");
    cfg.set("throttler", "enabled", "true");
    cfg.set("throttler", "share.Staging", "0.3");
    cfg.set("throttler", "share.Reprocessing", "0.3");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 3,
            files_per_dataset: 3,
            median_file_bytes: 300_000_000,
            derivations_per_day: 2,
            analysis_accesses_per_day: 20,
            seed: seed ^ 0xCA4,
            ..Default::default()
        },
        cfg,
    );
    driver.enable_invariant_checks(30 * MINUTE_MS);
    driver
}

/// Same grid, but with the background workload silenced: tests that
/// assert exact counters (staging queue drained, LRU victim counts) use
/// this so the only traffic is the campaign's own.
fn quiet_driver(seed: u64) -> Driver {
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "1h");
    cfg.set("throttler", "enabled", "true");
    cfg.set("throttler", "share.Staging", "0.3");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 0,
            files_per_dataset: 0,
            median_file_bytes: 1,
            derivations_per_day: 0,
            analysis_accesses_per_day: 0,
            seed,
            ..Default::default()
        },
        cfg,
    );
    driver.enable_invariant_checks(30 * MINUTE_MS);
    driver
}

/// Seed `n` datasets whose only replicas live on one tape RSE (an old
/// archive: the disk copies are long gone), tagged `datatype=<tag>` for
/// campaign selection and pinned there by an Ok rule.
fn seed_cold_archive(ctx: &Ctx, tape_rse: &str, tag: &str, n: usize, files_per: usize) {
    let cat = &ctx.catalog;
    let now = cat.now();
    let sys = ctx.fleet.get(tape_rse).expect("tape system exists");
    for d in 0..n {
        let ds = format!("cold.{d:03}");
        cat.add_dataset("data18", &ds, "prod").unwrap();
        let ds_key = DidKey::new("data18", &ds);
        cat.set_metadata(&ds_key, "datatype", tag).unwrap();
        for f in 0..files_per {
            let name = format!("cold.{d:03}.f{f}");
            let bytes = 200_000_000;
            let adler = synthetic_adler32_for(&name, bytes);
            cat.add_file("data18", &name, "prod", bytes, &adler, None).unwrap();
            let key = DidKey::new("data18", &name);
            cat.attach(&ds_key, &key).unwrap();
            let rep = cat.add_replica(tape_rse, &key, ReplicaState::Available, None).unwrap();
            // a put on a Tape system lands the file *unstaged* — reads
            // must go through the staging queue, like a real archive
            sys.put(&rep.pfn, bytes, now).unwrap();
        }
        let rid = cat
            .add_rule(RuleSpec::new("prod", ds_key.clone(), tape_rse, 1))
            .unwrap();
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok, "archive pin satisfied");
    }
}

fn season_specs() -> [CampaignSpec; 3] {
    [
        CampaignSpec::reprocessing("reprocess-raw", "data18", "datatype=RAW", "tier=2")
            .with_budget_hours(48),
        CampaignSpec::mass_deletion("sweep-aod", "mc20", "datatype=AOD").with_budget_hours(24),
        CampaignSpec::tape_carousel("carousel-cold", "data18", "datatype=COLD", "tier=2", 2)
            .with_budget_hours(48),
    ]
}

fn run_season_once(seed: u64) -> (Vec<rucio::analytics::campaigns::CampaignReport>, usize) {
    let mut driver = build_driver(seed);
    seed_cold_archive(&driver.ctx, "DE-T1-TAPE", "COLD", 4, 3);
    driver.run_days(1, 10 * MINUTE_MS); // warm-up: RAW lands, AODs derive
    let reports = run_season(&mut driver, &season_specs()).expect("season runs");
    driver.check_invariants_now();
    (reports, driver.violations.len())
}

// ---------------------------------------------------------------------
// determinism + invariants
// ---------------------------------------------------------------------

#[test]
fn fixed_seed_season_reports_are_identical() {
    let (a, va) = run_season_once(4242);
    let (b, vb) = run_season_once(4242);
    assert_eq!(va, 0, "first run: invariants clean at every checkpoint");
    assert_eq!(vb, 0, "second run: invariants clean at every checkpoint");
    assert_eq!(a.len(), 3);
    assert_eq!(a, b, "same seed must reproduce the campaign reports bit-for-bit");

    let repro = &a[0];
    assert_eq!(repro.kind, "reprocessing");
    assert!(repro.completed, "reprocessing converged: {repro:?}");
    assert!(repro.rules_created > 0, "bulk rules were injected");
    assert!(repro.locks_created >= repro.rules_created, "locks materialized per rule");
    assert_eq!(repro.batches_failed, 0);

    let sweep = &a[1];
    assert_eq!(sweep.kind, "mass-deletion");
    assert!(sweep.completed, "deletion sweep converged: {sweep:?}");

    let carousel = &a[2];
    assert_eq!(carousel.kind, "tape-carousel");
    assert!(carousel.completed, "carousel landed every wave: {carousel:?}");
    assert_eq!(carousel.waves, 2, "4 cold datasets in waves of 2");
    assert!(!carousel.link_cap_exceeded, "no link ever above its FTS cap");

    // reports carry the sampled curves for plotting
    for r in &a {
        assert!(!r.samples.is_empty() || r.time_to_complete_ms == Some(0), "{} sampled", r.name);
    }
}

// ---------------------------------------------------------------------
// carousel: link caps + batched staging
// ---------------------------------------------------------------------

#[test]
fn carousel_waves_respect_link_caps_and_stage_batches() {
    // Quiet grid: with no background traffic, every staging-queue entry
    // belongs to the carousel, so "the robot queue drained" is exact.
    let mut driver = quiet_driver(99);
    seed_cold_archive(&driver.ctx, "FR-T1-TAPE", "COLD", 4, 3);
    driver.run_days(1, 10 * MINUTE_MS);

    let spec =
        CampaignSpec::tape_carousel("carousel-cold", "data18", "datatype=COLD", "tier=2", 2)
            .with_budget_hours(48)
            .with_cadence(MINUTE_MS, MINUTE_MS); // fine-grained: catch the recall queue in flight
    let report = run_campaign(&mut driver, &spec).expect("carousel runs");
    driver.check_invariants_now();

    assert!(report.completed, "every wave landed: {report:?}");
    assert_eq!(report.waves, 2);
    assert_eq!(report.rules_created, 4, "one recall rule per dataset");
    assert!(
        report.max_wave_depth > 0,
        "the batched stage-in queue was actually exercised: {report:?}"
    );
    assert!(report.link_cap > 0);
    assert!(!report.link_cap_exceeded, "per-link FTS caps held throughout");
    assert!(
        report.peak_link_active() <= report.link_cap,
        "peak {} vs cap {}",
        report.peak_link_active(),
        report.link_cap
    );
    assert!(
        driver.violations.is_empty(),
        "invariants (incl. fts-link-caps) clean: {:?}",
        driver.violations
    );
    // the recall queue drained: nothing left pending on the robot
    assert_eq!(driver.ctx.fleet.staging_depth(), 0);
}

// ---------------------------------------------------------------------
// satellite: non-greedy reaper under a mass-deletion campaign
// ---------------------------------------------------------------------

#[test]
fn non_greedy_reaper_holds_watermark_under_mass_deletion() {
    // quiet grid: this test watches one cache RSE, not the workload
    let mut driver = quiet_driver(7);
    let ctx = driver.ctx.clone();
    let cat = ctx.catalog.clone();

    // A small non-greedy cache: capacity 10k, watermark 4k free.
    let now = cat.now();
    cat.add_rse(
        Rse::new("CACHE", now).with_attr("greedy", "false").with_attr("min_free", "4000"),
    )
    .unwrap();
    ctx.fleet.add(StorageSystem::new("CACHE", StorageKind::Disk, 10_000));

    // One dataset of six 1500-byte files, pinned to the cache.
    cat.add_dataset("data18", "tmp.cache", "prod").unwrap();
    let ds_key = DidKey::new("data18", "tmp.cache");
    cat.set_metadata(&ds_key, "datatype", "TMP").unwrap();
    let keys: Vec<DidKey> = (0..6)
        .map(|i| {
            let name = format!("tmp.f{i}");
            let adler = synthetic_adler32_for(&name, 1500);
            cat.add_file("data18", &name, "prod", 1500, &adler, None).unwrap();
            let key = DidKey::new("data18", &name);
            cat.attach(&ds_key, &key).unwrap();
            let rep = cat.add_replica("CACHE", &key, ReplicaState::Available, None).unwrap();
            ctx.fleet.get("CACHE").unwrap().put(&rep.pfn, 1500, cat.now()).unwrap();
            key
        })
        .collect();
    cat.add_rule(RuleSpec::new("prod", ds_key.clone(), "CACHE", 1)).unwrap();

    // Age the cache, then read f3..f5 — popularity comes ONLY from these
    // read traces, folded by the tracer daemon during the sim run.
    driver.run_span(2 * 3_600_000, MINUTE_MS, 30 * MINUTE_MS, |_| {});
    for key in &keys[3..] {
        emit_trace(&ctx.broker, cat.now(), "download", "CACHE", "data18", &key.name);
    }
    driver.run_span(10 * MINUTE_MS, MINUTE_MS, 10 * MINUTE_MS, |_| {});
    for key in &keys[3..] {
        let rep = cat.get_replica("CACHE", key).unwrap();
        assert!(rep.accessed_at > now, "read trace refreshed {}", key.name);
    }

    // Mass-deletion campaign over the cache dataset: the pin expires, all
    // six replicas become deletable — but the non-greedy reaper must only
    // evict down to the watermark, oldest-access first.
    let spec = CampaignSpec::mass_deletion("sweep-cache", "data18", "datatype=TMP")
        .with_budget_hours(24);
    let report = run_campaign(&mut driver, &spec).expect("sweep runs");
    driver.check_invariants_now();

    assert!(report.completed, "sweep converged: {report:?}");
    assert_eq!(report.rules_expired, 1, "the cache pin was expired");
    assert!(driver.violations.is_empty(), "{:?}", driver.violations);

    // Watermark respected mid-sweep: used 9000/free 1000 → evict exactly
    // two 1500-byte files to reach free >= 4000, then STOP even though
    // four deletable replicas remain cached.
    let free = ctx.fleet.get("CACHE").unwrap().free();
    assert!(free >= 4000, "watermark reached: free={free}");
    assert_eq!(cat.metrics.counter("reaper.lru_evicted"), 2, "stopped at the watermark");
    assert!(
        cat.metrics.counter("reaper.watermark_holds") >= 1,
        "later sweeps held at the watermark with deletable replicas still cached"
    );
    assert!(cat.metrics.counter("reaper.sweeps") >= 2, "multiple sweeps ran");

    // LRU honored: every read-traced file survives; both victims come
    // from the never-read cohort.
    for key in &keys[3..] {
        assert!(cat.get_replica("CACHE", key).is_ok(), "recently-read {} survives", key.name);
    }
    let untouched_left =
        keys[..3].iter().filter(|k| cat.get_replica("CACHE", k).is_ok()).count();
    assert_eq!(untouched_left, 1, "two oldest-access files were the victims");
}
