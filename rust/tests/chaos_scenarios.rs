//! Chaos scenario suite: declarative fault timelines injected into full
//! simulated-grid runs, each asserting the complete system-invariant set
//! (`sim::invariants`) *plus* a scenario-specific recovery property —
//! outage backlog drains, drained RSEs stop accreting data, partitions
//! heal, corruption is triaged, FTS blackouts queue-and-drain, daemon
//! crashes fail over via the heartbeat hash ring, and tape-recall storms
//! stage through the robots. A fixed seed reproduces identical per-day
//! stats across runs, so every assertion here is exact, not statistical.

use rucio::common::clock::{HOUR_MS, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState, RuleState};
use rucio::sim::driver::{standard_driver, Driver};
use rucio::sim::grid::GridSpec;
use rucio::sim::scenario::{Event, Scenario};
use rucio::sim::workload::WorkloadSpec;
use rucio::storagesim::synthetic_adler32_for;

/// 10 virtual minutes per discrete-event tick.
const TICK: i64 = 10 * MINUTE_MS;

/// Small chaos rig: one T2 per region, modest workload, fast reaper,
/// heartbeat TTL sized for the coarse virtual tick, invariant checks
/// every 2 virtual hours. Everything is seeded from `seed`.
fn chaos_driver(seed: u64) -> Driver {
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "1h");
    // live daemons tick every 10 virtual minutes; a 45-minute TTL keeps
    // them alive while letting a crashed instance expire within the run
    cfg.set("heartbeat", "ttl", "45m");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 4,
            files_per_dataset: 4,
            median_file_bytes: 500_000_000,
            derivations_per_day: 3,
            analysis_accesses_per_day: 40,
            seed: seed ^ 0xA0D,
            ..Default::default()
        },
        cfg,
    );
    driver.enable_invariant_checks(2 * HOUR_MS);
    driver
}

fn assert_no_violations(d: &Driver) {
    assert!(
        d.violations.is_empty(),
        "system invariants violated: {:?}",
        d.violations.iter().take(5).collect::<Vec<_>>()
    );
}

fn ok_fraction(d: &Driver) -> f64 {
    let cat = &d.ctx.catalog;
    let total = cat.rules.len().max(1);
    cat.rules_by_state.count(&RuleState::Ok) as f64 / total as f64
}

// ---------------------------------------------------------------------
// scenario 1: full site outage at the Tier-0 source
// ---------------------------------------------------------------------

#[test]
fn rse_outage_backlog_drains_and_rules_reconverge() {
    let mut d = chaos_driver(1001);
    d.run_days(1, TICK); // warm steady state
    let t0 = d.ctx.catalog.now();
    let fault_start = t0 + 4 * HOUR_MS;
    let fault_cleared = t0 + 28 * HOUR_MS;
    d.schedule_scenario(
        &Scenario::new("tier-0 outage")
            .at_hours(4, Event::RseDown { rse: "CERN-PROD".into() })
            .at_hours(28, Event::RseUp { rse: "CERN-PROD".into() }),
    );
    d.run_days(4, TICK);

    assert_no_violations(&d);
    // data produced during the outage never reached storage; the auditor
    // flags it lost against the storage dump and the necromancer strips
    // it from its datasets instead of leaving rules stuck forever
    let lost = d.ctx.catalog.metrics.counter("necromancer.lost");
    assert!(lost > 0, "outage uploads must surface as lost files");
    // the grid reconverges: backlog back at pre-fault level, stuck drained
    let report = d.recovery_report(fault_start, fault_cleared);
    assert!(
        report.reconverged_at.is_some(),
        "backlog must drain after recovery: {report:?}"
    );
    assert!(ok_fraction(&d) > 0.5, "rules mostly OK: {}", ok_fraction(&d));
}

// ---------------------------------------------------------------------
// scenario 2: drain — no new data, reads keep flowing
// ---------------------------------------------------------------------

#[test]
fn drained_rse_receives_no_new_data() {
    let mut d = chaos_driver(1002);
    d.run_days(1, TICK);
    let cat = d.ctx.catalog.clone();
    let drain_at = cat.now();
    d.schedule_scenario(
        &Scenario::new("drain CA-T2-1").at(0, Event::RseDrain { rse: "CA-T2-1".into() }),
    );
    d.run_days(2, TICK);

    assert_no_violations(&d);
    let fresh = cat
        .replicas
        .scan(|r| r.rse == "CA-T2-1" && r.created_at > drain_at);
    assert!(
        fresh.is_empty(),
        "drained RSE must not accrete data: {} fresh replicas",
        fresh.len()
    );
    let rse = cat.get_rse("CA-T2-1").unwrap();
    assert!(rse.availability_read && !rse.availability_write);
    assert!(ok_fraction(&d) > 0.5);
}

// ---------------------------------------------------------------------
// scenario 3: inter-region partition, then heal
// ---------------------------------------------------------------------

#[test]
fn network_partition_heals_and_converges() {
    let mut d = chaos_driver(1003);
    d.run_days(1, TICK);
    let t0 = d.ctx.catalog.now();
    d.schedule_scenario(
        &Scenario::new("DE/FR partition")
            .at_hours(2, Event::NetworkPartition { region_a: "DE".into(), region_b: "FR".into() })
            .at_hours(26, Event::NetworkRestore { region_a: "DE".into(), region_b: "FR".into() }),
    );
    d.run_days(3, TICK);

    assert_no_violations(&d);
    assert_eq!(d.ctx.net.fault_count(), 0, "all overlays cleared");
    let report = d.recovery_report(t0 + 2 * HOUR_MS, t0 + 26 * HOUR_MS);
    assert!(report.reconverged_at.is_some(), "{report:?}");
    assert!(ok_fraction(&d) > 0.5);
}

// ---------------------------------------------------------------------
// scenario 4: corruption burst — every copy rots; triage to lost
// ---------------------------------------------------------------------

#[test]
fn corruption_burst_is_triaged_by_necromancer() {
    let seed = 1004;
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "1h");
    cfg.set("heartbeat", "ttl", "45m");
    // one checksum strike is enough: corruption goes straight to BAD
    cfg.set("replicas", "suspicious_threshold", "1");
    let mut d = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 2,
            files_per_dataset: 2,
            derivations_per_day: 1,
            analysis_accesses_per_day: 10,
            seed: seed ^ 0xA0D,
            ..Default::default()
        },
        cfg,
    );
    d.enable_invariant_checks(2 * HOUR_MS);
    d.run_days(1, TICK);

    let cat = d.ctx.catalog.clone();
    let now = cat.now();
    // 6 files, each with two replicas — and both copies rot
    let mut keys = Vec::new();
    for i in 0..6 {
        let name = format!("chaos.rot{i:02}");
        let bytes = 50_000_000u64;
        let adler = synthetic_adler32_for(&name, bytes);
        cat.add_file("data18", &name, "root", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        for rse in ["UK-T1-DISK", "ND-T1-DISK"] {
            let rep = cat.add_replica(rse, &key, ReplicaState::Available, None).unwrap();
            let sys = d.ctx.fleet.get(rse).unwrap();
            sys.put(&rep.pfn, bytes, now).unwrap();
            sys.corrupt(&rep.pfn);
        }
        // a pin protects the (rotten) copies from the reaper, so triage —
        // not cache eviction — has to deal with them
        cat.add_rule(RuleSpec::new("root", key.clone(), "UK-T1-DISK|ND-T1-DISK", 2)).unwrap();
        // pulling them to a T2 forces reads of the rotten copies
        cat.add_rule(RuleSpec::new("root", key.clone(), "UK-T2-1", 1)).unwrap();
        keys.push(key);
    }
    d.run_days(2, TICK);

    assert_no_violations(&d);
    // every file went through checksum-fail → BAD → necromancer, and with
    // no clean copy anywhere ended as LOST with its rules cleaned up
    let lost = cat.metrics.counter("necromancer.lost");
    assert!(lost >= 6, "all rotten files triaged to lost, got {lost}");
    for key in &keys {
        assert!(
            cat.list_rules_for_did(key).is_empty(),
            "rules on lost {key} cleaned up"
        );
        assert!(cat.available_replicas(key).is_empty());
    }
    assert!(cat.metrics.counter("replicas.declared_bad") >= 6);
}

// ---------------------------------------------------------------------
// scenario 5: FTS failover, then full blackout — backlog queues & drains
// ---------------------------------------------------------------------

#[test]
fn fts_blackout_queues_backlog_then_drains() {
    let mut d = chaos_driver(1005);
    d.run_days(1, TICK);
    let t0 = d.ctx.catalog.now();
    d.schedule_scenario(
        &Scenario::new("fts outage ladder")
            // one server dies: the conveyor reroutes to the survivors
            .at_hours(2, Event::FtsDown { index: 0 })
            // total blackout: nothing can be submitted
            .at_hours(6, Event::FtsDown { index: 1 })
            .at_hours(6, Event::FtsDown { index: 2 })
            // everything returns
            .at_hours(18, Event::FtsUp { index: 0 })
            .at_hours(18, Event::FtsUp { index: 1 })
            .at_hours(18, Event::FtsUp { index: 2 })
            .at_hours(19, Event::DaemonCrash { daemon: "conveyor-poller".into(), which: 0 })
            .at_hours(22, Event::DaemonRestart { daemon: "conveyor-poller".into(), which: 0 }),
    );
    let before_blackout = d.ctx.fts.iter().map(|f| f.totals().0).sum::<u64>();
    d.run_days(2, TICK);

    assert_no_violations(&d);
    let after = d.ctx.fts.iter().map(|f| f.totals().0).sum::<u64>();
    assert!(after > before_blackout, "submissions resumed after recovery");
    let report = d.recovery_report(t0 + 6 * HOUR_MS, t0 + 18 * HOUR_MS);
    assert!(
        report.peak_backlog > report.baseline_backlog.max(4),
        "blackout builds a backlog: {report:?}"
    );
    assert!(report.reconverged_at.is_some(), "backlog drains: {report:?}");
    assert!(d.ctx.fts.iter().all(|f| f.is_online()));
    assert!(ok_fraction(&d) > 0.5);
}

// ---------------------------------------------------------------------
// scenario 6: daemon-instance crash — heartbeat hash ring failover
// ---------------------------------------------------------------------

#[test]
fn conveyor_failover_rebalances_and_converges() {
    let mut d = chaos_driver(1006);
    // a second conveyor submitter instance joins the fleet
    let sub2 = rucio::daemons::conveyor::Submitter::new(d.ctx.clone(), "sub-2");
    d.add_daemon(Box::new(sub2));
    d.run_days(1, TICK);
    let now = d.ctx.catalog.now();
    assert_eq!(
        d.ctx.heartbeats.live("submitter", now),
        2,
        "both instances beating"
    );
    // drop one instance's heartbeat mid-run
    d.schedule_scenario(&Scenario::new("submitter crash").at_hours(1, Event::DaemonCrash {
        daemon: "conveyor-submitter".into(),
        which: 1,
    }));
    d.run_days(2, TICK);

    assert_no_violations(&d);
    let now = d.ctx.catalog.now();
    assert_eq!(
        d.ctx.heartbeats.live("submitter", now),
        1,
        "hash ring rebalanced to the survivor"
    );
    // the surviving instance owns the whole queue: rules still converge
    assert!(ok_fraction(&d) > 0.5, "ok fraction: {}", ok_fraction(&d));
}

// ---------------------------------------------------------------------
// scenario 7: tape-recall storm
// ---------------------------------------------------------------------

#[test]
fn tape_recall_storm_stages_cold_data_to_disk() {
    let mut d = chaos_driver(1007);
    d.run_days(1, TICK);
    let cat = d.ctx.catalog.clone();
    let now = cat.now();
    // cold archival datasets: tape-only replicas, pinned on tape
    for i in 0..3 {
        let ds_name = format!("raw.cold{i}");
        cat.add_dataset("data18", &ds_name, "root").unwrap();
        let ds = DidKey::new("data18", &ds_name);
        for j in 0..3 {
            let fname = format!("{ds_name}.f{j}");
            let bytes = 100_000_000u64;
            let adler = synthetic_adler32_for(&fname, bytes);
            cat.add_file("data18", &fname, "root", bytes, &adler, None).unwrap();
            let key = DidKey::new("data18", &fname);
            let rep = cat.add_replica("CERN-TAPE", &key, ReplicaState::Available, None).unwrap();
            d.ctx.fleet.get("CERN-TAPE").unwrap().put(&rep.pfn, bytes, now).unwrap();
            cat.attach(&ds, &key).unwrap();
        }
        cat.close(&ds).unwrap();
        // archival pin so the reaper leaves the cold copies alone
        cat.add_rule(RuleSpec::new("root", ds.clone(), "CERN-TAPE", 1)).unwrap();
    }
    d.schedule_scenario(
        &Scenario::new("recall storm").at_hours(2, Event::TapeRecallStorm { datasets: 50 }),
    );
    d.run_days(2, TICK);

    assert_no_violations(&d);
    assert!(cat.metrics.counter("scenario.recall_storm_rules") >= 3);
    // every cold file was recalled through the robots onto T1 disk
    for i in 0..3 {
        for j in 0..3 {
            let key = DidKey::new("data18", &format!("raw.cold{i}.f{j}"));
            let on_disk = cat
                .available_replicas(&key)
                .iter()
                .any(|r| !cat.get_rse(&r.rse).unwrap().is_tape);
            assert!(on_disk, "cold file {key} must have a disk copy after the storm");
        }
    }
    let staging: Vec<_> = cat.rules.scan(|r| r.activity == "Staging");
    assert!(
        staging.iter().all(|r| r.state == RuleState::Ok),
        "staging rules converge: {:?}",
        staging.iter().map(|r| r.state).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------
// determinism: fixed seed ⇒ identical per-day stats, twice
// ---------------------------------------------------------------------

#[test]
fn fixed_seed_reproduces_identical_day_stats() {
    let run = |seed: u64| {
        let mut d = chaos_driver(seed);
        d.schedule_scenario(
            &Scenario::new("mixed incident day")
                .at_hours(6, Event::RseDown { rse: "ND-T2-1".into() })
                .at_hours(12, Event::NetworkDegrade {
                    src_region: "UK".into(),
                    dst_region: "IT".into(),
                    quality_mult: 0.3,
                    bandwidth_div: 10,
                })
                .at_hours(30, Event::RseUp { rse: "ND-T2-1".into() })
                .at_hours(36, Event::NetworkRestore {
                    region_a: "UK".into(),
                    region_b: "IT".into(),
                }),
        );
        d.run_days(2, TICK);
        assert_no_violations(&d);
        d.days
    };
    let a = run(4242);
    let b = run(4242);
    assert_eq!(a, b, "fixed seed must reproduce identical per-day stats");
    let c = run(4243);
    assert_ne!(a, c, "a different seed changes the run");
}
