//! In-process HTTP conformance suite for the REST surface (paper §3.3,
//! §4.1): auth token lifecycle over userpass and x509 (including 401s on
//! missing/expired/forged tokens), the error→status-code contract,
//! `x-rucio-next-cursor` pagination round-trips with malformed-cursor
//! 400s, the atomicity of the bulk routes (`POST /replicas/bulk`
//! all-or-nothing, `POST /rules/bulk` rollback), the cross-VO tenant
//! isolation matrix (every scope-addressed route × a foreign-VO token),
//! and the token-churn property (no interleaving of issue / expiry /
//! purge ever validates a stale token).

use std::sync::Arc;

use rucio::common::clock::{Clock, HOUR_MS, MINUTE_MS};
use rucio::core::types::{AccountType, AuthType};
use rucio::core::Catalog;
use rucio::httpd::{HttpClient, HttpServer};
use rucio::jsonx::Json;
use rucio::mq::Broker;

/// Server over a sim-clock catalog (so token expiry can be driven), with
/// alice (user) + root identities and one disk RSE.
fn server() -> (HttpServer, Arc<Catalog>) {
    let catalog = Arc::new(Catalog::new_for_tests());
    catalog.add_account("alice", AccountType::User, "a@x").unwrap();
    catalog
        .add_identity("alice", AuthType::UserPass, "alice", Some("pw"))
        .unwrap();
    catalog
        .add_identity("CN=Alice Example/OU=Physics", AuthType::X509, "alice", None)
        .unwrap();
    catalog
        .add_identity("root", AuthType::UserPass, "root", Some("rootpw"))
        .unwrap();
    catalog.add_rse(rucio::core::rse::Rse::new("X-DISK", 0)).unwrap();
    let srv = rucio::server::serve(catalog.clone(), Broker::new(), "127.0.0.1:0", 2).unwrap();
    (srv, catalog)
}

fn advance(cat: &Catalog, ms: i64) {
    match &cat.clock {
        Clock::Sim(s) => {
            s.advance(ms);
        }
        _ => panic!("conformance suite needs the sim clock"),
    }
}

/// Userpass login for `account`; returns a client carrying the token.
fn login(srv: &HttpServer, account: &str, password: &str) -> HttpClient {
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", account);
    c.set_header("x-rucio-username", account);
    c.set_header("x-rucio-password", password);
    let resp = c.get("/auth/userpass").unwrap();
    assert_eq!(resp.status, 200);
    let token = resp.header("x-rucio-auth-token").unwrap().to_string();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-auth-token", &token);
    c
}

/// Raw client carrying a valid alice token.
fn authed_client(srv: &HttpServer) -> HttpClient {
    login(srv, "alice", "pw")
}

// ---------------------------------------------------------------------
// auth token lifecycle
// ---------------------------------------------------------------------

#[test]
fn userpass_token_lifecycle_with_expiry() {
    let (srv, cat) = server();
    let raw = HttpClient::new(&srv.url());
    // no token at all → 401
    assert_eq!(raw.get("/scopes").unwrap().status, 401);
    // forged token → 401
    raw.set_header("x-rucio-auth-token", "forged-token");
    assert_eq!(raw.get("/scopes").unwrap().status, 401);

    // proper login issues a working token
    let c = authed_client(&srv);
    assert_eq!(c.get("/scopes").unwrap().status, 200);

    // tokens expire after [auth] token_lifetime (default 1h) of inactivity
    advance(&cat, 2 * HOUR_MS);
    let resp = c.get("/scopes").unwrap();
    assert_eq!(resp.status, 401, "expired token must be rejected");
    let body = resp.body_json().unwrap();
    assert!(body.req_str("error").unwrap().contains("expired"), "{body}");
}

#[test]
fn userpass_wrong_credentials_are_401() {
    let (srv, _cat) = server();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-username", "alice");
    c.set_header("x-rucio-password", "wrong");
    assert_eq!(c.get("/auth/userpass").unwrap().status, 401);
    // missing headers are a 401, not a 500
    let c = HttpClient::new(&srv.url());
    assert_eq!(c.get("/auth/userpass").unwrap().status, 401);
}

#[test]
fn x509_dn_token_works() {
    let (srv, _cat) = server();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-client-dn", "CN=Alice Example/OU=Physics");
    let resp = c.get("/auth/x509").unwrap();
    assert_eq!(resp.status, 200);
    let token = resp.header("x-rucio-auth-token").unwrap().to_string();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-auth-token", &token);
    assert_eq!(c.get("/scopes").unwrap().status, 200);
    // unknown DN → 401
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-client-dn", "CN=Mallory");
    assert_eq!(c.get("/auth/x509").unwrap().status, 401);
}

// ---------------------------------------------------------------------
// error → status-code mapping
// ---------------------------------------------------------------------

#[test]
fn error_status_code_contract() {
    let (srv, cat) = server();
    let c = authed_client(&srv);

    // 404: nonexistent DID / rule / route
    assert_eq!(c.get("/dids/user.alice/nope").unwrap().status, 404);
    assert_eq!(c.get("/rules/999999").unwrap().status, 404);
    assert_eq!(c.get("/no/such/route").unwrap().status, 404);
    // 405: known path, wrong method
    assert_eq!(c.delete("/ping").unwrap().status, 405);

    // 201 then 409: duplicate DID
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "aabbccdd");
    assert_eq!(c.post_json("/dids/user.alice/f1", &file).unwrap().status, 201);
    assert_eq!(c.post_json("/dids/user.alice/f1", &file).unwrap().status, 409);

    // 400: invalid DID type / malformed rule id
    let bad = Json::obj().with("type", "WEIRD");
    assert_eq!(c.post_json("/dids/user.alice/f2", &bad).unwrap().status, 400);
    assert_eq!(c.get("/rules/not-a-number").unwrap().status, 400);

    // 403: permission denied (alice creating an RSE)
    assert_eq!(c.post_json("/rses/EVIL", &Json::obj()).unwrap().status, 403);

    // 413: quota exceeded
    cat.set_account_limit("alice", "X-DISK", 5).unwrap();
    let rule = Json::obj()
        .with("scope", "user.alice")
        .with("name", "f1")
        .with("rse_expression", "X-DISK")
        .with("copies", 1u64);
    assert_eq!(c.post_json("/rules", &rule).unwrap().status, 413);
    // error body carries the machine-readable status
    let resp = c.post_json("/rules", &rule).unwrap();
    assert_eq!(resp.body_json().unwrap().req_u64("status").unwrap(), 413);
}

// ---------------------------------------------------------------------
// cursor pagination round-trips
// ---------------------------------------------------------------------

#[test]
fn cursor_pagination_round_trips_exactly_once() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for i in 0..23 {
        let file = Json::obj()
            .with("type", "FILE")
            .with("bytes", 10u64)
            .with("adler32", "aabbccdd");
        assert_eq!(
            c.post_json(&format!("/dids/user.alice/p{i:03}"), &file).unwrap().status,
            201
        );
        assert_eq!(
            c.post_json(
                &format!("/replicas/X-DISK/user.alice/p{i:03}"),
                &Json::obj()
            )
            .unwrap()
            .status,
            201
        );
    }

    // DID pages: every row exactly once, in name order, cursor as given
    let mut names: Vec<String> = Vec::new();
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(cur) => format!("/dids/user.alice?limit=7&cursor={cur}"),
            None => "/dids/user.alice?limit=7".to_string(),
        };
        let resp = c.get(&path).unwrap();
        assert_eq!(resp.status, 200);
        for row in resp.body_ndjson().unwrap() {
            names.push(row.req_str("name").unwrap().to_string());
        }
        match resp.header("x-rucio-next-cursor") {
            Some(next) => cursor = Some(next.to_string()),
            None => break,
        }
    }
    let expect: Vec<String> = (0..23).map(|i| format!("p{i:03}")).collect();
    assert_eq!(names, expect);

    // replica pages: structured cursor survives its percent-encoded trip
    let mut seen = 0usize;
    let mut pages = 0usize;
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(cur) => format!("/replicas?limit=9&cursor={cur}"),
            None => "/replicas?limit=9".to_string(),
        };
        let resp = c.get(&path).unwrap();
        assert_eq!(resp.status, 200);
        seen += resp.body_ndjson().unwrap().len();
        pages += 1;
        assert!(pages < 50, "cursor must make progress");
        match resp.header("x-rucio-next-cursor") {
            Some(next) => cursor = Some(next.to_string()),
            None => break,
        }
    }
    assert_eq!(seen, cat.replicas.len());
    assert_eq!(pages, 3, "23 replicas / 9 per page");

    // rule pages exist too (numeric cursor)
    let resp = c.get("/rules?limit=5").unwrap();
    assert_eq!(resp.status, 200);
}

#[test]
fn malformed_cursors_are_400() {
    let (srv, _cat) = server();
    let c = authed_client(&srv);
    assert_eq!(c.get("/rules?cursor=not-a-number").unwrap().status, 400);
    assert_eq!(c.get("/replicas?cursor=garbage-without-separators").unwrap().status, 400);
}

// ---------------------------------------------------------------------
// bulk atomicity
// ---------------------------------------------------------------------

#[test]
fn replicas_bulk_is_all_or_nothing() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for name in ["b0", "b1"] {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        c.post_json(&format!("/dids/user.alice/{name}"), &file).unwrap();
    }
    let ds = Json::obj().with("type", "DATASET");
    c.post_json("/dids/user.alice/myds", &ds).unwrap();

    // one bad entry (a dataset) fails the whole batch with no partial state
    let body = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![
            Json::obj().with("scope", "user.alice").with("name", "b0"),
            Json::obj().with("scope", "user.alice").with("name", "myds"),
        ]),
    );
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(cat.replicas.len(), 0, "no partial registration");

    // the clean batch lands in one call
    let body = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![
            Json::obj().with("scope", "user.alice").with("name", "b0"),
            Json::obj().with("scope", "user.alice").with("name", "b1"),
        ]),
    );
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 201);
    assert_eq!(resp.body_json().unwrap().req_u64("added").unwrap(), 2);
    assert_eq!(cat.replicas.len(), 2);

    // replaying the identical batch is a duplicate → atomic failure
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 409);
    assert_eq!(cat.replicas.len(), 2);
}

#[test]
fn rules_bulk_rolls_back_on_mid_batch_failure() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for name in ["r0", "r1"] {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        c.post_json(&format!("/dids/user.alice/{name}"), &file).unwrap();
    }
    // second spec resolves to an empty RSE set → whole call fails and the
    // first rule (already created) is rolled back
    let body = Json::obj().with(
        "rules",
        Json::Arr(vec![
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r0")
                .with("rse_expression", "X-DISK"),
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r1")
                .with("rse_expression", "tier=99"),
        ]),
    );
    let resp = c.post_json("/rules/bulk", &body).unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(cat.rules.len(), 0, "first rule rolled back");
    assert_eq!(cat.locks.len(), 0);
    assert_eq!(
        cat.requests_by_state.count(&rucio::core::types::RequestState::Queued),
        0
    );

    // the clean batch creates both and reports ids
    let body = Json::obj().with(
        "rules",
        Json::Arr(vec![
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r0")
                .with("rse_expression", "X-DISK"),
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r1")
                .with("rse_expression", "X-DISK"),
        ]),
    );
    let resp = c.post_json("/rules/bulk", &body).unwrap();
    assert_eq!(resp.status, 201);
    let ids = resp.body_json().unwrap();
    assert_eq!(ids.get("rule_ids").and_then(Json::as_arr).unwrap().len(), 2);
    assert_eq!(cat.rules.len(), 2);
}

// ---------------------------------------------------------------------
// multi-VO tenant isolation
// ---------------------------------------------------------------------

/// Provision two tenants (atlas / cms) on the shared instance, each with
/// a userpass identity (password "pw") and the home scope that
/// `add_account_vo` creates.
fn two_tenants(cat: &Catalog) {
    for (acct, vo) in [("at1", "atlas"), ("cm1", "cms")] {
        cat.add_account_vo(acct, AccountType::User, "", vo).unwrap();
        cat.add_identity(acct, AuthType::UserPass, acct, Some("pw")).unwrap();
    }
}

#[test]
fn cross_vo_isolation_matrix() {
    let (srv, cat) = server();
    two_tenants(&cat);
    let at = login(&srv, "at1", "pw");
    let cm = login(&srv, "cm1", "pw");

    // Each tenant provisions data in its home scope — every own-VO write
    // route answers 2xx.
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
    let mut rule_id = std::collections::BTreeMap::new();
    for (c, scope) in [(&at, "user.at1"), (&cm, "user.cm1")] {
        assert_eq!(c.post_json(&format!("/dids/{scope}/f1"), &file).unwrap().status, 201);
        let ds = Json::obj().with("type", "DATASET");
        assert_eq!(c.post_json(&format!("/dids/{scope}/ds"), &ds).unwrap().status, 201);
        let att = Json::obj().with("child_scope", scope).with("child_name", "f1");
        assert_eq!(c.post_json(&format!("/attachments/{scope}/ds"), &att).unwrap().status, 201);
        assert_eq!(
            c.post_json(&format!("/replicas/X-DISK/{scope}/f1"), &Json::obj()).unwrap().status,
            201
        );
        let rule = Json::obj()
            .with("scope", scope)
            .with("name", "f1")
            .with("rse_expression", "X-DISK");
        let resp = c.post_json("/rules", &rule).unwrap();
        assert_eq!(resp.status, 201);
        rule_id.insert(scope, resp.body_json().unwrap().req_u64("rule_id").unwrap());
        let meta = Json::obj().with("campaign", "mc26");
        assert_eq!(c.post_json(&format!("/meta/{scope}/f1"), &meta).unwrap().status, 201);
    }
    let at_rule = rule_id["user.at1"];

    // The matrix: every scope-addressed route × the foreign-VO token
    // → 403. cms may neither read nor write anything under user.at1.
    assert_eq!(cm.get("/dids/user.at1").unwrap().status, 403);
    assert_eq!(cm.get("/dids/user.at1/f1").unwrap().status, 403);
    assert_eq!(cm.get("/meta/user.at1/f1").unwrap().status, 403);
    assert_eq!(
        cm.post_json("/meta/user.at1/f1", &Json::obj().with("k", "v")).unwrap().status,
        403
    );
    assert_eq!(cm.post_json("/dids/user.at1/sneak", &file).unwrap().status, 403);
    let att = Json::obj().with("child_scope", "user.at1").with("child_name", "f1");
    assert_eq!(cm.post_json("/attachments/user.at1/ds", &att).unwrap().status, 403);
    assert_eq!(cm.get("/replicas/user.at1/f1").unwrap().status, 403);
    assert_eq!(
        cm.post_json("/replicas/X-DISK/user.at1/f1", &Json::obj()).unwrap().status,
        403
    );
    let bulk = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![Json::obj().with("scope", "user.at1").with("name", "f1")]),
    );
    assert_eq!(cm.post_json("/replicas/bulk", &bulk).unwrap().status, 403);
    let rule = Json::obj()
        .with("scope", "user.at1")
        .with("name", "f1")
        .with("rse_expression", "X-DISK");
    assert_eq!(cm.post_json("/rules", &rule).unwrap().status, 403);
    let bulk = Json::obj().with("rules", Json::Arr(vec![rule.clone()]));
    assert_eq!(cm.post_json("/rules/bulk", &bulk).unwrap().status, 403);
    assert_eq!(cm.get(&format!("/rules/{at_rule}")).unwrap().status, 403);
    assert_eq!(cm.delete(&format!("/rules/{at_rule}")).unwrap().status, 403);
    assert_eq!(cm.get("/dids/user.at1/f1/rules").unwrap().status, 403);
    assert_eq!(cm.get("/accounts/at1/usage/X-DISK").unwrap().status, 403);
    // admin-gated provisioning routes refuse a plain foreign tenant too
    let acc = Json::obj().with("vo", "atlas");
    assert_eq!(cm.post_json("/accounts/sneak", &acc).unwrap().status, 403);
    let sc = Json::obj().with("account", "at1");
    assert_eq!(cm.post_json("/scopes/s-sneak", &sc).unwrap().status, 403);

    // Guard precedence: a foreign scope 403s even for names that do not
    // exist (no existence oracle), while an unknown scope is a plain 404.
    assert_eq!(cm.get("/dids/user.at1/no-such-name").unwrap().status, 403);
    assert_eq!(cm.get("/dids/no.such.scope/x").unwrap().status, 404);

    // Own-VO reads on the same routes are 2xx.
    assert_eq!(at.get("/dids/user.at1").unwrap().status, 200);
    assert_eq!(at.get("/dids/user.at1/f1").unwrap().status, 200);
    assert_eq!(at.get("/meta/user.at1/f1").unwrap().status, 200);
    assert_eq!(at.get("/replicas/user.at1/f1").unwrap().status, 200);
    assert_eq!(at.get(&format!("/rules/{at_rule}")).unwrap().status, 200);
    assert_eq!(at.get("/dids/user.at1/f1/rules").unwrap().status, 200);
    assert_eq!(at.get("/accounts/at1/usage/X-DISK").unwrap().status, 200);

    // List routes filter rather than 403: cms sees its own rows and no
    // atlas rows on /scopes, /replicas and /rules.
    let scopes: Vec<String> = cm
        .get("/scopes")
        .unwrap()
        .body_ndjson()
        .unwrap()
        .iter()
        .map(|r| r.req_str("scope").unwrap().to_string())
        .collect();
    assert!(scopes.contains(&"user.cm1".to_string()), "{scopes:?}");
    assert!(!scopes.contains(&"user.at1".to_string()), "{scopes:?}");
    let reps = cm.get("/replicas").unwrap().body_ndjson().unwrap();
    assert!(reps.iter().all(|r| r.req_str("scope").unwrap() != "user.at1"));
    assert!(reps.iter().any(|r| r.req_str("scope").unwrap() == "user.cm1"));
    let rules = cm.get("/rules").unwrap().body_ndjson().unwrap();
    assert!(rules.iter().all(|r| r.req_str("scope").unwrap() != "user.at1"));
    assert!(rules.iter().any(|r| r.req_str("scope").unwrap() == "user.cm1"));

    // The default-VO admin is the instance operator and crosses tenants.
    let root = login(&srv, "root", "rootpw");
    let scopes: Vec<String> = root
        .get("/scopes")
        .unwrap()
        .body_ndjson()
        .unwrap()
        .iter()
        .map(|r| r.req_str("scope").unwrap().to_string())
        .collect();
    assert!(scopes.contains(&"user.at1".to_string()));
    assert!(scopes.contains(&"user.cm1".to_string()));
    assert_eq!(root.get("/dids/user.at1/f1").unwrap().status, 200);
    assert_eq!(root.get("/dids/user.cm1/f1").unwrap().status, 200);

    // The owner can still tear down its own rule.
    assert_eq!(at.delete(&format!("/rules/{at_rule}")).unwrap().status, 200);
}

// ---------------------------------------------------------------------
// token revocation + churn
// ---------------------------------------------------------------------

#[test]
fn suspending_an_account_revokes_its_live_tokens() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    assert_eq!(c.get("/scopes").unwrap().status, 200);

    // suspension must bite on the very next validation, not at expiry
    cat.suspend_account("alice").unwrap();
    let resp = c.get("/scopes").unwrap();
    assert_eq!(resp.status, 401, "old token must die with the account");
    assert!(
        resp.body_json().unwrap().req_str("error").unwrap().contains("suspended"),
    );
    // and re-authentication is refused too
    let raw = HttpClient::new(&srv.url());
    raw.set_header("x-rucio-account", "alice");
    raw.set_header("x-rucio-username", "alice");
    raw.set_header("x-rucio-password", "pw");
    assert_eq!(raw.get("/auth/userpass").unwrap().status, 401);
}

#[test]
fn token_churn_never_validates_stale_tokens() {
    let (srv, cat) = server();
    // Interleave issue / expire / purge over six 40-minute rounds (token
    // lifetime is 1h): after every step, every live token must validate
    // and every expired one must 401 — whether or not housekeeping has
    // purged its row yet.
    let mut live: Vec<(String, i64)> = Vec::new();
    let mut dead: Vec<String> = Vec::new();
    for round in 0..6 {
        for _ in 0..2 {
            let c = HttpClient::new(&srv.url());
            c.set_header("x-rucio-account", "alice");
            c.set_header("x-rucio-username", "alice");
            c.set_header("x-rucio-password", "pw");
            let resp = c.get("/auth/userpass").unwrap();
            assert_eq!(resp.status, 200);
            let token = resp.header("x-rucio-auth-token").unwrap().to_string();
            live.push((token, cat.now() + HOUR_MS));
        }
        advance(&cat, 40 * MINUTE_MS);
        let now = cat.now();
        let (expired, still): (Vec<(String, i64)>, Vec<(String, i64)>) =
            live.into_iter().partition(|(_, exp)| *exp < now);
        live = still;
        dead.extend(expired.into_iter().map(|(t, _)| t));
        if round % 2 == 1 {
            cat.purge_expired_tokens();
        }
        for (token, _) in &live {
            let c = HttpClient::new(&srv.url());
            c.set_header("x-rucio-auth-token", token);
            assert_eq!(c.get("/scopes").unwrap().status, 200, "live token rejected");
        }
        for token in &dead {
            let c = HttpClient::new(&srv.url());
            c.set_header("x-rucio-auth-token", token);
            assert_eq!(c.get("/scopes").unwrap().status, 401, "stale token accepted");
        }
    }
    assert!(!dead.is_empty(), "the interleaving must actually expire tokens");

    // final sweep: everything still outstanding expires and purges away
    advance(&cat, 2 * HOUR_MS);
    assert_eq!(cat.purge_expired_tokens(), live.len());
    assert_eq!(cat.tokens.len(), 0);
    for (token, _) in &live {
        let c = HttpClient::new(&srv.url());
        c.set_header("x-rucio-auth-token", token);
        assert_eq!(c.get("/scopes").unwrap().status, 401);
    }
}
