//! In-process HTTP conformance suite for the REST surface (paper §3.3,
//! §4.1): auth token lifecycle over userpass and x509 (including 401s on
//! missing/expired/forged tokens), the error→status-code contract,
//! `x-rucio-next-cursor` pagination round-trips with malformed-cursor
//! 400s, and the atomicity of the bulk routes (`POST /replicas/bulk`
//! all-or-nothing, `POST /rules/bulk` rollback).

use std::sync::Arc;

use rucio::common::clock::{Clock, HOUR_MS};
use rucio::core::types::{AccountType, AuthType};
use rucio::core::Catalog;
use rucio::httpd::{HttpClient, HttpServer};
use rucio::jsonx::Json;
use rucio::mq::Broker;

/// Server over a sim-clock catalog (so token expiry can be driven), with
/// alice (user) + root identities and one disk RSE.
fn server() -> (HttpServer, Arc<Catalog>) {
    let catalog = Arc::new(Catalog::new_for_tests());
    catalog.add_account("alice", AccountType::User, "a@x").unwrap();
    catalog
        .add_identity("alice", AuthType::UserPass, "alice", Some("pw"))
        .unwrap();
    catalog
        .add_identity("CN=Alice Example/OU=Physics", AuthType::X509, "alice", None)
        .unwrap();
    catalog
        .add_identity("root", AuthType::UserPass, "root", Some("rootpw"))
        .unwrap();
    catalog.add_rse(rucio::core::rse::Rse::new("X-DISK", 0)).unwrap();
    let srv = rucio::server::serve(catalog.clone(), Broker::new(), "127.0.0.1:0", 2).unwrap();
    (srv, catalog)
}

fn advance(cat: &Catalog, ms: i64) {
    match &cat.clock {
        Clock::Sim(s) => {
            s.advance(ms);
        }
        _ => panic!("conformance suite needs the sim clock"),
    }
}

/// Raw client carrying a valid alice token.
fn authed_client(srv: &HttpServer) -> HttpClient {
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-username", "alice");
    c.set_header("x-rucio-password", "pw");
    let resp = c.get("/auth/userpass").unwrap();
    assert_eq!(resp.status, 200);
    let token = resp.header("x-rucio-auth-token").unwrap().to_string();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-auth-token", &token);
    c
}

// ---------------------------------------------------------------------
// auth token lifecycle
// ---------------------------------------------------------------------

#[test]
fn userpass_token_lifecycle_with_expiry() {
    let (srv, cat) = server();
    let raw = HttpClient::new(&srv.url());
    // no token at all → 401
    assert_eq!(raw.get("/scopes").unwrap().status, 401);
    // forged token → 401
    raw.set_header("x-rucio-auth-token", "forged-token");
    assert_eq!(raw.get("/scopes").unwrap().status, 401);

    // proper login issues a working token
    let c = authed_client(&srv);
    assert_eq!(c.get("/scopes").unwrap().status, 200);

    // tokens expire after [auth] token_lifetime (default 1h) of inactivity
    advance(&cat, 2 * HOUR_MS);
    let resp = c.get("/scopes").unwrap();
    assert_eq!(resp.status, 401, "expired token must be rejected");
    let body = resp.body_json().unwrap();
    assert!(body.req_str("error").unwrap().contains("expired"), "{body}");
}

#[test]
fn userpass_wrong_credentials_are_401() {
    let (srv, _cat) = server();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-username", "alice");
    c.set_header("x-rucio-password", "wrong");
    assert_eq!(c.get("/auth/userpass").unwrap().status, 401);
    // missing headers are a 401, not a 500
    let c = HttpClient::new(&srv.url());
    assert_eq!(c.get("/auth/userpass").unwrap().status, 401);
}

#[test]
fn x509_dn_token_works() {
    let (srv, _cat) = server();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-client-dn", "CN=Alice Example/OU=Physics");
    let resp = c.get("/auth/x509").unwrap();
    assert_eq!(resp.status, 200);
    let token = resp.header("x-rucio-auth-token").unwrap().to_string();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-auth-token", &token);
    assert_eq!(c.get("/scopes").unwrap().status, 200);
    // unknown DN → 401
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-client-dn", "CN=Mallory");
    assert_eq!(c.get("/auth/x509").unwrap().status, 401);
}

// ---------------------------------------------------------------------
// error → status-code mapping
// ---------------------------------------------------------------------

#[test]
fn error_status_code_contract() {
    let (srv, cat) = server();
    let c = authed_client(&srv);

    // 404: nonexistent DID / rule / route
    assert_eq!(c.get("/dids/user.alice/nope").unwrap().status, 404);
    assert_eq!(c.get("/rules/999999").unwrap().status, 404);
    assert_eq!(c.get("/no/such/route").unwrap().status, 404);
    // 405: known path, wrong method
    assert_eq!(c.delete("/ping").unwrap().status, 405);

    // 201 then 409: duplicate DID
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "aabbccdd");
    assert_eq!(c.post_json("/dids/user.alice/f1", &file).unwrap().status, 201);
    assert_eq!(c.post_json("/dids/user.alice/f1", &file).unwrap().status, 409);

    // 400: invalid DID type / malformed rule id
    let bad = Json::obj().with("type", "WEIRD");
    assert_eq!(c.post_json("/dids/user.alice/f2", &bad).unwrap().status, 400);
    assert_eq!(c.get("/rules/not-a-number").unwrap().status, 400);

    // 403: permission denied (alice creating an RSE)
    assert_eq!(c.post_json("/rses/EVIL", &Json::obj()).unwrap().status, 403);

    // 413: quota exceeded
    cat.set_account_limit("alice", "X-DISK", 5).unwrap();
    let rule = Json::obj()
        .with("scope", "user.alice")
        .with("name", "f1")
        .with("rse_expression", "X-DISK")
        .with("copies", 1u64);
    assert_eq!(c.post_json("/rules", &rule).unwrap().status, 413);
    // error body carries the machine-readable status
    let resp = c.post_json("/rules", &rule).unwrap();
    assert_eq!(resp.body_json().unwrap().req_u64("status").unwrap(), 413);
}

// ---------------------------------------------------------------------
// cursor pagination round-trips
// ---------------------------------------------------------------------

#[test]
fn cursor_pagination_round_trips_exactly_once() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for i in 0..23 {
        let file = Json::obj()
            .with("type", "FILE")
            .with("bytes", 10u64)
            .with("adler32", "aabbccdd");
        assert_eq!(
            c.post_json(&format!("/dids/user.alice/p{i:03}"), &file).unwrap().status,
            201
        );
        assert_eq!(
            c.post_json(
                &format!("/replicas/X-DISK/user.alice/p{i:03}"),
                &Json::obj()
            )
            .unwrap()
            .status,
            201
        );
    }

    // DID pages: every row exactly once, in name order, cursor as given
    let mut names: Vec<String> = Vec::new();
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(cur) => format!("/dids/user.alice?limit=7&cursor={cur}"),
            None => "/dids/user.alice?limit=7".to_string(),
        };
        let resp = c.get(&path).unwrap();
        assert_eq!(resp.status, 200);
        for row in resp.body_ndjson().unwrap() {
            names.push(row.req_str("name").unwrap().to_string());
        }
        match resp.header("x-rucio-next-cursor") {
            Some(next) => cursor = Some(next.to_string()),
            None => break,
        }
    }
    let expect: Vec<String> = (0..23).map(|i| format!("p{i:03}")).collect();
    assert_eq!(names, expect);

    // replica pages: structured cursor survives its percent-encoded trip
    let mut seen = 0usize;
    let mut pages = 0usize;
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(cur) => format!("/replicas?limit=9&cursor={cur}"),
            None => "/replicas?limit=9".to_string(),
        };
        let resp = c.get(&path).unwrap();
        assert_eq!(resp.status, 200);
        seen += resp.body_ndjson().unwrap().len();
        pages += 1;
        assert!(pages < 50, "cursor must make progress");
        match resp.header("x-rucio-next-cursor") {
            Some(next) => cursor = Some(next.to_string()),
            None => break,
        }
    }
    assert_eq!(seen, cat.replicas.len());
    assert_eq!(pages, 3, "23 replicas / 9 per page");

    // rule pages exist too (numeric cursor)
    let resp = c.get("/rules?limit=5").unwrap();
    assert_eq!(resp.status, 200);
}

#[test]
fn malformed_cursors_are_400() {
    let (srv, _cat) = server();
    let c = authed_client(&srv);
    assert_eq!(c.get("/rules?cursor=not-a-number").unwrap().status, 400);
    assert_eq!(c.get("/replicas?cursor=garbage-without-separators").unwrap().status, 400);
}

// ---------------------------------------------------------------------
// bulk atomicity
// ---------------------------------------------------------------------

#[test]
fn replicas_bulk_is_all_or_nothing() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for name in ["b0", "b1"] {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        c.post_json(&format!("/dids/user.alice/{name}"), &file).unwrap();
    }
    let ds = Json::obj().with("type", "DATASET");
    c.post_json("/dids/user.alice/myds", &ds).unwrap();

    // one bad entry (a dataset) fails the whole batch with no partial state
    let body = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![
            Json::obj().with("scope", "user.alice").with("name", "b0"),
            Json::obj().with("scope", "user.alice").with("name", "myds"),
        ]),
    );
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(cat.replicas.len(), 0, "no partial registration");

    // the clean batch lands in one call
    let body = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![
            Json::obj().with("scope", "user.alice").with("name", "b0"),
            Json::obj().with("scope", "user.alice").with("name", "b1"),
        ]),
    );
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 201);
    assert_eq!(resp.body_json().unwrap().req_u64("added").unwrap(), 2);
    assert_eq!(cat.replicas.len(), 2);

    // replaying the identical batch is a duplicate → atomic failure
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 409);
    assert_eq!(cat.replicas.len(), 2);
}

#[test]
fn rules_bulk_rolls_back_on_mid_batch_failure() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for name in ["r0", "r1"] {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        c.post_json(&format!("/dids/user.alice/{name}"), &file).unwrap();
    }
    // second spec resolves to an empty RSE set → whole call fails and the
    // first rule (already created) is rolled back
    let body = Json::obj().with(
        "rules",
        Json::Arr(vec![
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r0")
                .with("rse_expression", "X-DISK"),
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r1")
                .with("rse_expression", "tier=99"),
        ]),
    );
    let resp = c.post_json("/rules/bulk", &body).unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(cat.rules.len(), 0, "first rule rolled back");
    assert_eq!(cat.locks.len(), 0);
    assert_eq!(
        cat.requests_by_state.count(&rucio::core::types::RequestState::Queued),
        0
    );

    // the clean batch creates both and reports ids
    let body = Json::obj().with(
        "rules",
        Json::Arr(vec![
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r0")
                .with("rse_expression", "X-DISK"),
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r1")
                .with("rse_expression", "X-DISK"),
        ]),
    );
    let resp = c.post_json("/rules/bulk", &body).unwrap();
    assert_eq!(resp.status, 201);
    let ids = resp.body_json().unwrap();
    assert_eq!(ids.get("rule_ids").and_then(Json::as_arr).unwrap().len(), 2);
    assert_eq!(cat.rules.len(), 2);
}
