//! In-process HTTP conformance suite for the REST surface (paper §3.3,
//! §4.1): auth token lifecycle over userpass and x509 (including 401s on
//! missing/expired/forged tokens), the error→status-code contract,
//! `x-rucio-next-cursor` pagination round-trips with malformed-cursor
//! 400s, the atomicity of the bulk routes (`POST /replicas/bulk`
//! all-or-nothing, `POST /rules/bulk` rollback), the cross-VO tenant
//! isolation matrix (every scope-addressed route × a foreign-VO token),
//! and the token-churn property (no interleaving of issue / expiry /
//! purge ever validates a stale token).

use std::sync::Arc;

use rucio::common::clock::{Clock, HOUR_MS, MINUTE_MS};
use rucio::core::types::{AccountType, AuthType};
use rucio::core::Catalog;
use rucio::httpd::{HttpClient, HttpServer};
use rucio::jsonx::Json;
use rucio::mq::Broker;

/// Server over a sim-clock catalog (so token expiry can be driven), with
/// alice (user) + root identities and one disk RSE.
fn server() -> (HttpServer, Arc<Catalog>) {
    let catalog = Arc::new(Catalog::new_for_tests());
    catalog.add_account("alice", AccountType::User, "a@x").unwrap();
    catalog
        .add_identity("alice", AuthType::UserPass, "alice", Some("pw"))
        .unwrap();
    catalog
        .add_identity("CN=Alice Example/OU=Physics", AuthType::X509, "alice", None)
        .unwrap();
    catalog
        .add_identity("root", AuthType::UserPass, "root", Some("rootpw"))
        .unwrap();
    catalog.add_rse(rucio::core::rse::Rse::new("X-DISK", 0)).unwrap();
    let srv = rucio::server::serve(catalog.clone(), Broker::new(), "127.0.0.1:0", 2).unwrap();
    (srv, catalog)
}

fn advance(cat: &Catalog, ms: i64) {
    match &cat.clock {
        Clock::Sim(s) => {
            s.advance(ms);
        }
        _ => panic!("conformance suite needs the sim clock"),
    }
}

/// Userpass login for `account`; returns a client carrying the token.
fn login(srv: &HttpServer, account: &str, password: &str) -> HttpClient {
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", account);
    c.set_header("x-rucio-username", account);
    c.set_header("x-rucio-password", password);
    let resp = c.get("/auth/userpass").unwrap();
    assert_eq!(resp.status, 200);
    let token = resp.header("x-rucio-auth-token").unwrap().to_string();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-auth-token", &token);
    c
}

/// Raw client carrying a valid alice token.
fn authed_client(srv: &HttpServer) -> HttpClient {
    login(srv, "alice", "pw")
}

// ---------------------------------------------------------------------
// auth token lifecycle
// ---------------------------------------------------------------------

#[test]
fn userpass_token_lifecycle_with_expiry() {
    let (srv, cat) = server();
    let raw = HttpClient::new(&srv.url());
    // no token at all → 401
    assert_eq!(raw.get("/scopes").unwrap().status, 401);
    // forged token → 401
    raw.set_header("x-rucio-auth-token", "forged-token");
    assert_eq!(raw.get("/scopes").unwrap().status, 401);

    // proper login issues a working token
    let c = authed_client(&srv);
    assert_eq!(c.get("/scopes").unwrap().status, 200);

    // tokens expire after [auth] token_lifetime (default 1h) of inactivity
    advance(&cat, 2 * HOUR_MS);
    let resp = c.get("/scopes").unwrap();
    assert_eq!(resp.status, 401, "expired token must be rejected");
    let body = resp.body_json().unwrap();
    let env = body.get("error").expect("error envelope");
    assert_eq!(env.req_str("code").unwrap(), "CannotAuthenticate");
    assert!(env.req_str("message").unwrap().contains("expired"), "{body}");
}

#[test]
fn userpass_wrong_credentials_are_401() {
    let (srv, _cat) = server();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-username", "alice");
    c.set_header("x-rucio-password", "wrong");
    assert_eq!(c.get("/auth/userpass").unwrap().status, 401);
    // missing headers are a 401, not a 500
    let c = HttpClient::new(&srv.url());
    assert_eq!(c.get("/auth/userpass").unwrap().status, 401);
}

#[test]
fn x509_dn_token_works() {
    let (srv, _cat) = server();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-client-dn", "CN=Alice Example/OU=Physics");
    let resp = c.get("/auth/x509").unwrap();
    assert_eq!(resp.status, 200);
    let token = resp.header("x-rucio-auth-token").unwrap().to_string();
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-auth-token", &token);
    assert_eq!(c.get("/scopes").unwrap().status, 200);
    // unknown DN → 401
    let c = HttpClient::new(&srv.url());
    c.set_header("x-rucio-account", "alice");
    c.set_header("x-rucio-client-dn", "CN=Mallory");
    assert_eq!(c.get("/auth/x509").unwrap().status, 401);
}

// ---------------------------------------------------------------------
// error → status-code mapping
// ---------------------------------------------------------------------

#[test]
fn error_status_code_contract() {
    let (srv, cat) = server();
    let c = authed_client(&srv);

    // 404: nonexistent DID / rule / route
    assert_eq!(c.get("/dids/user.alice/nope").unwrap().status, 404);
    assert_eq!(c.get("/rules/999999").unwrap().status, 404);
    assert_eq!(c.get("/no/such/route").unwrap().status, 404);
    // 405: known path, wrong method
    assert_eq!(c.delete("/ping").unwrap().status, 405);

    // 201 then 409: duplicate DID
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "aabbccdd");
    assert_eq!(c.post_json("/dids/user.alice/f1", &file).unwrap().status, 201);
    assert_eq!(c.post_json("/dids/user.alice/f1", &file).unwrap().status, 409);

    // 400: invalid DID type / malformed rule id
    let bad = Json::obj().with("type", "WEIRD");
    assert_eq!(c.post_json("/dids/user.alice/f2", &bad).unwrap().status, 400);
    assert_eq!(c.get("/rules/not-a-number").unwrap().status, 400);

    // 403: permission denied (alice creating an RSE)
    assert_eq!(c.post_json("/rses/EVIL", &Json::obj()).unwrap().status, 403);

    // 413: quota exceeded
    cat.set_account_limit("alice", "X-DISK", 5).unwrap();
    let rule = Json::obj()
        .with("scope", "user.alice")
        .with("name", "f1")
        .with("rse_expression", "X-DISK")
        .with("copies", 1u64);
    assert_eq!(c.post_json("/rules", &rule).unwrap().status, 413);
    // error body carries the machine-readable envelope
    let resp = c.post_json("/rules", &rule).unwrap();
    let body = resp.body_json().unwrap();
    let env = body.get("error").expect("error envelope");
    assert_eq!(env.req_str("code").unwrap(), "QuotaExceeded");
    assert!(env.req_str("message").unwrap().contains("quota"), "{body}");
}

// ---------------------------------------------------------------------
// cursor pagination round-trips
// ---------------------------------------------------------------------

#[test]
fn cursor_pagination_round_trips_exactly_once() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for i in 0..23 {
        let file = Json::obj()
            .with("type", "FILE")
            .with("bytes", 10u64)
            .with("adler32", "aabbccdd");
        assert_eq!(
            c.post_json(&format!("/dids/user.alice/p{i:03}"), &file).unwrap().status,
            201
        );
        assert_eq!(
            c.post_json(
                &format!("/replicas/X-DISK/user.alice/p{i:03}"),
                &Json::obj()
            )
            .unwrap()
            .status,
            201
        );
    }

    // DID pages: every row exactly once, in name order, cursor as given
    let mut names: Vec<String> = Vec::new();
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(cur) => format!("/dids/user.alice?limit=7&cursor={cur}"),
            None => "/dids/user.alice?limit=7".to_string(),
        };
        let resp = c.get(&path).unwrap();
        assert_eq!(resp.status, 200);
        for row in resp.body_ndjson().unwrap() {
            names.push(row.req_str("name").unwrap().to_string());
        }
        match resp.header("x-rucio-next-cursor") {
            Some(next) => cursor = Some(next.to_string()),
            None => break,
        }
    }
    let expect: Vec<String> = (0..23).map(|i| format!("p{i:03}")).collect();
    assert_eq!(names, expect);

    // replica pages: structured cursor survives its percent-encoded trip
    let mut seen = 0usize;
    let mut pages = 0usize;
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(cur) => format!("/replicas?limit=9&cursor={cur}"),
            None => "/replicas?limit=9".to_string(),
        };
        let resp = c.get(&path).unwrap();
        assert_eq!(resp.status, 200);
        seen += resp.body_ndjson().unwrap().len();
        pages += 1;
        assert!(pages < 50, "cursor must make progress");
        match resp.header("x-rucio-next-cursor") {
            Some(next) => cursor = Some(next.to_string()),
            None => break,
        }
    }
    assert_eq!(seen, cat.replicas.len());
    assert_eq!(pages, 3, "23 replicas / 9 per page");

    // rule pages exist too (numeric cursor)
    let resp = c.get("/rules?limit=5").unwrap();
    assert_eq!(resp.status, 200);
}

#[test]
fn malformed_cursors_are_400() {
    let (srv, _cat) = server();
    let c = authed_client(&srv);
    // every structured-cursor route rejects garbage with the envelope
    for path in [
        "/rules?cursor=not-a-number",
        "/requests?cursor=not-a-number",
        "/replicas?cursor=garbage-without-separators",
    ] {
        assert_envelope(&c.get(path).unwrap(), 400, "InvalidValue");
    }
}

/// Walk a paginated NDJSON route page by page; returns (rows, pages).
fn walk_pages(c: &HttpClient, base: &str, limit: usize) -> (usize, usize) {
    let sep = if base.contains('?') { '&' } else { '?' };
    let (mut rows, mut pages) = (0usize, 0usize);
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(cur) => format!("{base}{sep}limit={limit}&cursor={cur}"),
            None => format!("{base}{sep}limit={limit}"),
        };
        let resp = c.get(&path).unwrap();
        assert_eq!(resp.status, 200, "{path}");
        let page = resp.body_ndjson().unwrap();
        assert!(page.len() <= limit, "page overflows limit on {path}");
        rows += page.len();
        pages += 1;
        assert!(pages < 100, "cursor must make progress on {base}");
        match resp.header("x-rucio-next-cursor") {
            Some(next) => cursor = Some(next.to_string()),
            None => break,
        }
    }
    (rows, pages)
}

#[test]
fn pagination_contract_holds_on_all_four_cursor_routes() {
    let (srv, _cat) = server();
    let c = authed_client(&srv);
    // 12 files with replicas (rules over them complete instantly), plus
    // 6 replica-less files whose rules stay as queued transfer requests.
    for i in 0..12 {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        assert_eq!(c.post_json(&format!("/dids/user.alice/p{i:02}"), &file).unwrap().status, 201);
        assert_eq!(
            c.post_json(&format!("/replicas/X-DISK/user.alice/p{i:02}"), &Json::obj())
                .unwrap()
                .status,
            201
        );
        let rule = Json::obj()
            .with("scope", "user.alice")
            .with("name", format!("p{i:02}"))
            .with("rse_expression", "X-DISK");
        assert_eq!(c.post_json("/rules", &rule).unwrap().status, 201);
    }
    for i in 0..6 {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        assert_eq!(c.post_json(&format!("/dids/user.alice/q{i:02}"), &file).unwrap().status, 201);
        let rule = Json::obj()
            .with("scope", "user.alice")
            .with("name", format!("q{i:02}"))
            .with("rse_expression", "X-DISK");
        assert_eq!(c.post_json("/rules", &rule).unwrap().status, 201);
    }

    // Same limit/cursor params, same header, exactly-once coverage —
    // on every one of the four routes.
    assert_eq!(walk_pages(&c, "/dids/user.alice", 5), (18, 4));
    assert_eq!(walk_pages(&c, "/replicas", 5), (12, 3));
    assert_eq!(walk_pages(&c, "/rules", 5), (18, 4));
    assert_eq!(walk_pages(&c, "/requests", 5), (6, 2));
    // the shared limit clamp: limit=0 is lifted to 1, not a crash
    let resp = c.get("/dids/user.alice?limit=0").unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body_ndjson().unwrap().len(), 1);
}

// ---------------------------------------------------------------------
// error envelope shape
// ---------------------------------------------------------------------

/// Assert one error response: the expected status plus the uniform
/// `{"error": {"code", "message"}}` body — and nothing else in it.
fn assert_envelope(resp: &rucio::httpd::Response, status: u16, code: &str) {
    assert_eq!(resp.status, status, "{}", String::from_utf8_lossy(&resp.body));
    let body = resp.body_json().unwrap();
    assert_eq!(body.as_obj().map(|o| o.len()), Some(1), "envelope only: {body}");
    let env = body.get("error").expect("error envelope");
    assert_eq!(env.req_str("code").unwrap(), code, "{body}");
    assert!(!env.req_str("message").unwrap().is_empty(), "{body}");
}

#[test]
fn every_error_path_answers_the_same_envelope() {
    let (srv, cat) = server();
    // unauthenticated: missing and forged tokens
    let raw = HttpClient::new(&srv.url());
    assert_envelope(&raw.get("/scopes").unwrap(), 401, "CannotAuthenticate");
    raw.set_header("x-rucio-auth-token", "forged");
    assert_envelope(&raw.get("/scopes").unwrap(), 401, "CannotAuthenticate");

    let c = authed_client(&srv);
    // 404s (missing DID / rule / route) and 405 (wrong method): even the
    // router's own fallbacks speak the envelope
    assert_envelope(&c.get("/dids/user.alice/nope").unwrap(), 404, "DidNotFound");
    assert_envelope(&c.get("/rules/999999").unwrap(), 404, "RuleNotFound");
    assert_envelope(&c.get("/no/such/route").unwrap(), 404, "RouteNotFound");
    assert_envelope(&c.delete("/ping").unwrap(), 405, "MethodNotAllowed");
    // 400s: bad DID type, bad id, malformed metadata filter
    let bad = Json::obj().with("type", "WEIRD");
    assert_envelope(&c.post_json("/dids/user.alice/w", &bad).unwrap(), 400, "InvalidValue");
    assert_envelope(&c.get("/rules/not-a-number").unwrap(), 400, "InvalidValue");
    assert_envelope(
        &c.get("/dids/user.alice?filter=run%3E%3DRAW").unwrap(),
        400,
        "InvalidMetaExpression",
    );
    // 403 / 409 / 413
    assert_envelope(&c.post_json("/rses/EVIL", &Json::obj()).unwrap(), 403, "AccessDenied");
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
    assert_eq!(c.post_json("/dids/user.alice/f1", &file).unwrap().status, 201);
    assert_envelope(&c.post_json("/dids/user.alice/f1", &file).unwrap(), 409, "Duplicate");
    cat.set_account_limit("alice", "X-DISK", 5).unwrap();
    let rule = Json::obj()
        .with("scope", "user.alice")
        .with("name", "f1")
        .with("rse_expression", "X-DISK")
        .with("copies", 1u64);
    assert_envelope(&c.post_json("/rules", &rule).unwrap(), 413, "QuotaExceeded");
}

// ---------------------------------------------------------------------
// placement & rebalancing surface
// ---------------------------------------------------------------------

#[test]
fn popularity_route_reports_the_heat_signal() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
    assert_eq!(c.post_json("/dids/user.alice/hot", &file).unwrap().status, 201);

    // never read → zeroed signal
    let resp = c.get("/dids/user.alice/hot/popularity").unwrap();
    assert_eq!(resp.status, 200);
    let j = resp.body_json().unwrap();
    assert_eq!(j.req_u64("accesses").unwrap(), 0);
    assert_eq!(j.get("heat_score").and_then(Json::as_f64), Some(0.0));

    // three accesses land in both counters and the decayed score
    let key = rucio::core::types::DidKey::new("user.alice", "hot");
    for _ in 0..3 {
        cat.touch_replica("X-DISK", &key);
    }
    let j = c.get("/dids/user.alice/hot/popularity").unwrap().body_json().unwrap();
    assert_eq!(j.req_u64("accesses").unwrap(), 3);
    let score = j.get("heat_score").and_then(Json::as_f64).unwrap();
    assert!(score > 2.9 && score <= 3.0, "fresh heat ≈ 3, got {score}");
    assert!(j.req_u64("heat_half_life_ms").unwrap() > 0);

    // unknown name under an owned scope is a plain 404
    assert_envelope(&c.get("/dids/user.alice/cold/popularity").unwrap(), 404, "DidNotFound");
}

#[test]
fn new_routes_hold_the_tenant_and_admin_gates() {
    let (srv, cat) = server();
    two_tenants(&cat);
    let at = login(&srv, "at1", "pw");
    let cm = login(&srv, "cm1", "pw");
    let root = login(&srv, "root", "rootpw");
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
    assert_eq!(at.post_json("/dids/user.at1/f1", &file).unwrap().status, 201);

    // popularity: guarded like every scope-addressed read — foreign VO
    // 403s even for names that don't exist, own VO and the operator read
    assert_envelope(&cm.get("/dids/user.at1/f1/popularity").unwrap(), 403, "AccessDenied");
    assert_envelope(&cm.get("/dids/user.at1/ghost/popularity").unwrap(), 403, "AccessDenied");
    assert_eq!(at.get("/dids/user.at1/f1/popularity").unwrap().status, 200);
    assert_eq!(root.get("/dids/user.at1/f1/popularity").unwrap().status, 200);

    // rebalance status spans every tenant → instance operator only
    assert_envelope(&at.get("/rebalance/status").unwrap(), 403, "AccessDenied");
    assert_envelope(&cm.get("/rebalance/status").unwrap(), 403, "AccessDenied");
    let j = root.get("/rebalance/status").unwrap().body_json().unwrap();
    assert_eq!(j.req_u64("live_moves").unwrap(), 0);
    assert!(j.get("decommissions").and_then(Json::as_arr).unwrap().is_empty());

    // decommission: plain tenants and VO admins are refused, the
    // operator flags the RSE for the BB8 daemon
    assert_envelope(
        &cm.post_json("/rses/X-DISK/decommission", &Json::obj()).unwrap(),
        403,
        "AccessDenied",
    );
    assert_envelope(
        &root.post_json("/rses/GHOST-RSE/decommission", &Json::obj()).unwrap(),
        404,
        "RseNotFound",
    );
    let resp = root.post_json("/rses/X-DISK/decommission", &Json::obj()).unwrap();
    assert_eq!(resp.status, 202);
    assert_eq!(resp.body_json().unwrap().req_str("decommission").unwrap(), "pending");
    assert_eq!(cat.get_rse("X-DISK").unwrap().attr("decommission"), Some("pending"));
    // flagging again never restarts the lifecycle
    cat.set_rse_attribute("X-DISK", "decommission", "draining").unwrap();
    let resp = root.post_json("/rses/X-DISK/decommission", &Json::obj()).unwrap();
    assert_eq!(resp.status, 202);
    assert_eq!(resp.body_json().unwrap().req_str("decommission").unwrap(), "draining");
    // and the ledger shows up in the status view
    let j = root.get("/rebalance/status").unwrap().body_json().unwrap();
    let decoms = j.get("decommissions").and_then(Json::as_arr).unwrap();
    assert_eq!(decoms.len(), 1);
    assert_eq!(decoms[0].req_str("rse").unwrap(), "X-DISK");
    assert_eq!(decoms[0].req_str("state").unwrap(), "draining");
}

// ---------------------------------------------------------------------
// bulk atomicity
// ---------------------------------------------------------------------

#[test]
fn replicas_bulk_is_all_or_nothing() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for name in ["b0", "b1"] {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        c.post_json(&format!("/dids/user.alice/{name}"), &file).unwrap();
    }
    let ds = Json::obj().with("type", "DATASET");
    c.post_json("/dids/user.alice/myds", &ds).unwrap();

    // one bad entry (a dataset) fails the whole batch with no partial state
    let body = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![
            Json::obj().with("scope", "user.alice").with("name", "b0"),
            Json::obj().with("scope", "user.alice").with("name", "myds"),
        ]),
    );
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(cat.replicas.len(), 0, "no partial registration");

    // the clean batch lands in one call
    let body = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![
            Json::obj().with("scope", "user.alice").with("name", "b0"),
            Json::obj().with("scope", "user.alice").with("name", "b1"),
        ]),
    );
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 201);
    assert_eq!(resp.body_json().unwrap().req_u64("added").unwrap(), 2);
    assert_eq!(cat.replicas.len(), 2);

    // replaying the identical batch is a duplicate → atomic failure
    let resp = c.post_json("/replicas/bulk", &body).unwrap();
    assert_eq!(resp.status, 409);
    assert_eq!(cat.replicas.len(), 2);
}

#[test]
fn rules_bulk_rolls_back_on_mid_batch_failure() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    for name in ["r0", "r1"] {
        let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
        c.post_json(&format!("/dids/user.alice/{name}"), &file).unwrap();
    }
    // second spec resolves to an empty RSE set → whole call fails and the
    // first rule (already created) is rolled back
    let body = Json::obj().with(
        "rules",
        Json::Arr(vec![
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r0")
                .with("rse_expression", "X-DISK"),
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r1")
                .with("rse_expression", "tier=99"),
        ]),
    );
    let resp = c.post_json("/rules/bulk", &body).unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(cat.rules.len(), 0, "first rule rolled back");
    assert_eq!(cat.locks.len(), 0);
    assert_eq!(
        cat.requests_by_state.count(&rucio::core::types::RequestState::Queued),
        0
    );

    // the clean batch creates both and reports ids
    let body = Json::obj().with(
        "rules",
        Json::Arr(vec![
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r0")
                .with("rse_expression", "X-DISK"),
            Json::obj()
                .with("scope", "user.alice")
                .with("name", "r1")
                .with("rse_expression", "X-DISK"),
        ]),
    );
    let resp = c.post_json("/rules/bulk", &body).unwrap();
    assert_eq!(resp.status, 201);
    let ids = resp.body_json().unwrap();
    assert_eq!(ids.get("rule_ids").and_then(Json::as_arr).unwrap().len(), 2);
    assert_eq!(cat.rules.len(), 2);
}

// ---------------------------------------------------------------------
// multi-VO tenant isolation
// ---------------------------------------------------------------------

/// Provision two tenants (atlas / cms) on the shared instance, each with
/// a userpass identity (password "pw") and the home scope that
/// `add_account_vo` creates.
fn two_tenants(cat: &Catalog) {
    for (acct, vo) in [("at1", "atlas"), ("cm1", "cms")] {
        cat.add_account_vo(acct, AccountType::User, "", vo).unwrap();
        cat.add_identity(acct, AuthType::UserPass, acct, Some("pw")).unwrap();
    }
}

#[test]
fn cross_vo_isolation_matrix() {
    let (srv, cat) = server();
    two_tenants(&cat);
    let at = login(&srv, "at1", "pw");
    let cm = login(&srv, "cm1", "pw");

    // Each tenant provisions data in its home scope — every own-VO write
    // route answers 2xx.
    let file = Json::obj().with("type", "FILE").with("bytes", 10u64).with("adler32", "x");
    let mut rule_id = std::collections::BTreeMap::new();
    for (c, scope) in [(&at, "user.at1"), (&cm, "user.cm1")] {
        assert_eq!(c.post_json(&format!("/dids/{scope}/f1"), &file).unwrap().status, 201);
        let ds = Json::obj().with("type", "DATASET");
        assert_eq!(c.post_json(&format!("/dids/{scope}/ds"), &ds).unwrap().status, 201);
        let att = Json::obj().with("child_scope", scope).with("child_name", "f1");
        assert_eq!(c.post_json(&format!("/attachments/{scope}/ds"), &att).unwrap().status, 201);
        assert_eq!(
            c.post_json(&format!("/replicas/X-DISK/{scope}/f1"), &Json::obj()).unwrap().status,
            201
        );
        let rule = Json::obj()
            .with("scope", scope)
            .with("name", "f1")
            .with("rse_expression", "X-DISK");
        let resp = c.post_json("/rules", &rule).unwrap();
        assert_eq!(resp.status, 201);
        rule_id.insert(scope, resp.body_json().unwrap().req_u64("rule_id").unwrap());
        let meta = Json::obj().with("campaign", "mc26");
        assert_eq!(c.post_json(&format!("/meta/{scope}/f1"), &meta).unwrap().status, 201);
    }
    let at_rule = rule_id["user.at1"];

    // The matrix: every scope-addressed route × the foreign-VO token
    // → 403. cms may neither read nor write anything under user.at1.
    assert_eq!(cm.get("/dids/user.at1").unwrap().status, 403);
    assert_eq!(cm.get("/dids/user.at1/f1").unwrap().status, 403);
    assert_eq!(cm.get("/meta/user.at1/f1").unwrap().status, 403);
    assert_eq!(
        cm.post_json("/meta/user.at1/f1", &Json::obj().with("k", "v")).unwrap().status,
        403
    );
    assert_eq!(cm.post_json("/dids/user.at1/sneak", &file).unwrap().status, 403);
    let att = Json::obj().with("child_scope", "user.at1").with("child_name", "f1");
    assert_eq!(cm.post_json("/attachments/user.at1/ds", &att).unwrap().status, 403);
    assert_eq!(cm.get("/replicas/user.at1/f1").unwrap().status, 403);
    assert_eq!(
        cm.post_json("/replicas/X-DISK/user.at1/f1", &Json::obj()).unwrap().status,
        403
    );
    let bulk = Json::obj().with("rse", "X-DISK").with(
        "replicas",
        Json::Arr(vec![Json::obj().with("scope", "user.at1").with("name", "f1")]),
    );
    assert_eq!(cm.post_json("/replicas/bulk", &bulk).unwrap().status, 403);
    let rule = Json::obj()
        .with("scope", "user.at1")
        .with("name", "f1")
        .with("rse_expression", "X-DISK");
    assert_eq!(cm.post_json("/rules", &rule).unwrap().status, 403);
    let bulk = Json::obj().with("rules", Json::Arr(vec![rule.clone()]));
    assert_eq!(cm.post_json("/rules/bulk", &bulk).unwrap().status, 403);
    assert_eq!(cm.get(&format!("/rules/{at_rule}")).unwrap().status, 403);
    assert_eq!(cm.delete(&format!("/rules/{at_rule}")).unwrap().status, 403);
    assert_eq!(cm.get("/dids/user.at1/f1/rules").unwrap().status, 403);
    assert_eq!(cm.get("/accounts/at1/usage/X-DISK").unwrap().status, 403);
    // admin-gated provisioning routes refuse a plain foreign tenant too
    let acc = Json::obj().with("vo", "atlas");
    assert_eq!(cm.post_json("/accounts/sneak", &acc).unwrap().status, 403);
    let sc = Json::obj().with("account", "at1");
    assert_eq!(cm.post_json("/scopes/s-sneak", &sc).unwrap().status, 403);

    // Guard precedence: a foreign scope 403s even for names that do not
    // exist (no existence oracle), while an unknown scope is a plain 404.
    assert_eq!(cm.get("/dids/user.at1/no-such-name").unwrap().status, 403);
    assert_eq!(cm.get("/dids/no.such.scope/x").unwrap().status, 404);

    // Own-VO reads on the same routes are 2xx.
    assert_eq!(at.get("/dids/user.at1").unwrap().status, 200);
    assert_eq!(at.get("/dids/user.at1/f1").unwrap().status, 200);
    assert_eq!(at.get("/meta/user.at1/f1").unwrap().status, 200);
    assert_eq!(at.get("/replicas/user.at1/f1").unwrap().status, 200);
    assert_eq!(at.get(&format!("/rules/{at_rule}")).unwrap().status, 200);
    assert_eq!(at.get("/dids/user.at1/f1/rules").unwrap().status, 200);
    assert_eq!(at.get("/accounts/at1/usage/X-DISK").unwrap().status, 200);

    // List routes filter rather than 403: cms sees its own rows and no
    // atlas rows on /scopes, /replicas and /rules.
    let scopes: Vec<String> = cm
        .get("/scopes")
        .unwrap()
        .body_ndjson()
        .unwrap()
        .iter()
        .map(|r| r.req_str("scope").unwrap().to_string())
        .collect();
    assert!(scopes.contains(&"user.cm1".to_string()), "{scopes:?}");
    assert!(!scopes.contains(&"user.at1".to_string()), "{scopes:?}");
    let reps = cm.get("/replicas").unwrap().body_ndjson().unwrap();
    assert!(reps.iter().all(|r| r.req_str("scope").unwrap() != "user.at1"));
    assert!(reps.iter().any(|r| r.req_str("scope").unwrap() == "user.cm1"));
    let rules = cm.get("/rules").unwrap().body_ndjson().unwrap();
    assert!(rules.iter().all(|r| r.req_str("scope").unwrap() != "user.at1"));
    assert!(rules.iter().any(|r| r.req_str("scope").unwrap() == "user.cm1"));

    // The default-VO admin is the instance operator and crosses tenants.
    let root = login(&srv, "root", "rootpw");
    let scopes: Vec<String> = root
        .get("/scopes")
        .unwrap()
        .body_ndjson()
        .unwrap()
        .iter()
        .map(|r| r.req_str("scope").unwrap().to_string())
        .collect();
    assert!(scopes.contains(&"user.at1".to_string()));
    assert!(scopes.contains(&"user.cm1".to_string()));
    assert_eq!(root.get("/dids/user.at1/f1").unwrap().status, 200);
    assert_eq!(root.get("/dids/user.cm1/f1").unwrap().status, 200);

    // The owner can still tear down its own rule.
    assert_eq!(at.delete(&format!("/rules/{at_rule}")).unwrap().status, 200);
}

// ---------------------------------------------------------------------
// token revocation + churn
// ---------------------------------------------------------------------

#[test]
fn suspending_an_account_revokes_its_live_tokens() {
    let (srv, cat) = server();
    let c = authed_client(&srv);
    assert_eq!(c.get("/scopes").unwrap().status, 200);

    // suspension must bite on the very next validation, not at expiry
    cat.suspend_account("alice").unwrap();
    let resp = c.get("/scopes").unwrap();
    assert_eq!(resp.status, 401, "old token must die with the account");
    let body = resp.body_json().unwrap();
    assert!(
        body.get("error").unwrap().req_str("message").unwrap().contains("suspended"),
        "{body}"
    );
    // and re-authentication is refused too
    let raw = HttpClient::new(&srv.url());
    raw.set_header("x-rucio-account", "alice");
    raw.set_header("x-rucio-username", "alice");
    raw.set_header("x-rucio-password", "pw");
    assert_eq!(raw.get("/auth/userpass").unwrap().status, 401);
}

#[test]
fn token_churn_never_validates_stale_tokens() {
    let (srv, cat) = server();
    // Interleave issue / expire / purge over six 40-minute rounds (token
    // lifetime is 1h): after every step, every live token must validate
    // and every expired one must 401 — whether or not housekeeping has
    // purged its row yet.
    let mut live: Vec<(String, i64)> = Vec::new();
    let mut dead: Vec<String> = Vec::new();
    for round in 0..6 {
        for _ in 0..2 {
            let c = HttpClient::new(&srv.url());
            c.set_header("x-rucio-account", "alice");
            c.set_header("x-rucio-username", "alice");
            c.set_header("x-rucio-password", "pw");
            let resp = c.get("/auth/userpass").unwrap();
            assert_eq!(resp.status, 200);
            let token = resp.header("x-rucio-auth-token").unwrap().to_string();
            live.push((token, cat.now() + HOUR_MS));
        }
        advance(&cat, 40 * MINUTE_MS);
        let now = cat.now();
        let (expired, still): (Vec<(String, i64)>, Vec<(String, i64)>) =
            live.into_iter().partition(|(_, exp)| *exp < now);
        live = still;
        dead.extend(expired.into_iter().map(|(t, _)| t));
        if round % 2 == 1 {
            cat.purge_expired_tokens();
        }
        for (token, _) in &live {
            let c = HttpClient::new(&srv.url());
            c.set_header("x-rucio-auth-token", token);
            assert_eq!(c.get("/scopes").unwrap().status, 200, "live token rejected");
        }
        for token in &dead {
            let c = HttpClient::new(&srv.url());
            c.set_header("x-rucio-auth-token", token);
            assert_eq!(c.get("/scopes").unwrap().status, 401, "stale token accepted");
        }
    }
    assert!(!dead.is_empty(), "the interleaving must actually expire tokens");

    // final sweep: everything still outstanding expires and purges away
    advance(&cat, 2 * HOUR_MS);
    assert_eq!(cat.purge_expired_tokens(), live.len());
    assert_eq!(cat.tokens.len(), 0);
    for (token, _) in &live {
        let c = HttpClient::new(&srv.url());
        c.set_header("x-rucio-auth-token", token);
        assert_eq!(c.get("/scopes").unwrap().status, 401);
    }
}
