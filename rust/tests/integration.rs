//! Cross-layer integration tests: full rule→transfer→replica convergence
//! through the daemon fleet under virtual time, failure recovery, and
//! the monitoring surfaces.

use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::core::types::RuleState;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;

#[test]
fn one_week_convergence_and_monitoring() {
    // Every PRNG stream is pinned explicitly, and `standard_driver`
    // threads the grid seed through the catalog PRNG, the per-endpoint
    // storage fault streams, and the FTS quality rolls. A fixed-seed run
    // is therefore bit-for-bit deterministic (chaos_scenarios.rs asserts
    // identical per-day stats across repeated runs), so the thresholds
    // below are exact checks on one known trajectory, not statistical
    // gambles over a random one.
    let mut cfg = Config::new();
    cfg.set("common", "seed", "42");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed: 42, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 6,
            derivations_per_day: 4,
            analysis_accesses_per_day: 60,
            seed: 7,
            ..Default::default()
        },
        cfg,
    );
    driver.run_days(7, 10 * MINUTE_MS);
    let cat = driver.ctx.catalog.clone();

    // Tolerance bands (all wide of the observed trajectory on purpose, so
    // legitimate behaviour changes in other subsystems don't trip them):
    // * rule volume — a week of this workload creates several hundred
    //   rules; >50 guards against the workload silently stalling;
    // * convergence — modelled failure rates are ~4–10% with repair
    //   active, so OK-fraction sits far above the 0.70 floor;
    // * failure rate — the paper reports 10–20% transfer failures at
    //   scale; 0.35 only catches systemic breakage (e.g. a dead retry
    //   path), not modelled flakiness.
    let total = cat.rules.len();
    let ok = cat.rules_by_state.count(&RuleState::Ok);
    assert!(total > 50, "rules created: {total}");
    assert!(
        ok as f64 > total as f64 * 0.7,
        "most rules OK: {ok}/{total}"
    );

    // volume grew and transfers happened
    let last = driver.days.last().unwrap();
    assert!(last.bytes_managed > 0);
    let done: u64 = driver.days.iter().map(|d| d.transfers_done).sum();
    let failed: u64 = driver.days.iter().map(|d| d.transfers_failed).sum();
    assert!(done > 200, "transfers done: {done}");
    let fail_rate = failed as f64 / (done + failed) as f64;
    assert!(fail_rate < 0.35, "failure rate sane: {fail_rate:.2}");

    // deletions happened (lifetimes + reaper)
    let deletions: u64 = driver.days.iter().map(|d| d.deletions).sum();
    assert!(deletions > 0, "reaper active");

    // monitoring surfaces populated
    assert!(cat.metrics.counter("transfers.done") > 0);
    let acc = rucio::analytics::reports::storage_accounting(&cat);
    assert!(!acc.is_empty());
    // every report row matches a real RSE
    for rse in acc.keys() {
        assert!(cat.get_rse(rse).is_ok());
    }

    // efficiency matrix sane
    for (_, eff) in driver.efficiency_matrix() {
        assert!((0.0..=1.0).contains(&eff));
    }
}

#[test]
fn heartbeat_failover_rebalances_work() {
    use rucio::daemons::heartbeat::Heartbeats;
    let h = Heartbeats::with_ttl(1000);
    let (_, n1) = h.beat("conveyor", "a", 0);
    assert_eq!(n1, 1);
    h.beat("conveyor", "b", 100);
    let (_, n2) = h.beat("conveyor", "a", 200);
    assert_eq!(n2, 2);
    // b dies; a takes over after TTL
    let (_, n3) = h.beat("conveyor", "a", 5000);
    assert_eq!(n3, 1);
}
