//! End-to-end dynamic placement & rebalancing: the heat-driven C3PO
//! daemon and the BB8 decommission lifecycle running inside the full
//! simulated grid with the complete invariant suite on (including the
//! cache-rule-backing and heat-agreement invariants). A flash crowd
//! makes one dataset go viral: caches must appear while the crowd is
//! hot, and be reaped — rules expired, copies deleted — once it passes.

use rucio::common::clock::{HOUR_MS, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState};
use rucio::placement::CACHE_ACTIVITY;
use rucio::sim::driver::{standard_driver, Driver};
use rucio::sim::grid::GridSpec;
use rucio::sim::scenario::{Event, Scenario};
use rucio::sim::workload::WorkloadSpec;
use rucio::storagesim::synthetic_adler32_for;

/// 10 virtual minutes per discrete-event tick.
const TICK: i64 = 10 * MINUTE_MS;

/// Placement rig: small grid, modest workload, invariant checks every 2
/// virtual hours. Caches live 36 virtual hours and heat halves every 6,
/// so one crowd's caches are created and reaped inside a few days.
fn placement_driver(seed: u64) -> Driver {
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "1h");
    cfg.set("heartbeat", "ttl", "45m");
    cfg.set("c3po", "lifetime", "36h");
    cfg.set("heat", "half_life", "6h");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 2,
            files_per_dataset: 2,
            derivations_per_day: 1,
            analysis_accesses_per_day: 10,
            seed: seed ^ 0xA0D,
            ..Default::default()
        },
        cfg,
    );
    driver.enable_invariant_checks(2 * HOUR_MS);
    driver
}

fn assert_no_violations(d: &Driver) {
    assert!(
        d.violations.is_empty(),
        "system invariants violated: {:?}",
        d.violations.iter().take(5).collect::<Vec<_>>()
    );
}

/// A closed 3-file dataset resident (and pinned) on DE-T1-DISK.
fn seed_viral_dataset(d: &Driver) -> (DidKey, Vec<DidKey>) {
    let cat = d.ctx.catalog.clone();
    let now = cat.now();
    cat.add_dataset("data18", "viral.ds", "root").unwrap();
    let ds = DidKey::new("data18", "viral.ds");
    let mut files = Vec::new();
    for j in 0..3 {
        let fname = format!("viral.f{j}");
        let bytes = 50_000_000u64;
        let adler = synthetic_adler32_for(&fname, bytes);
        cat.add_file("data18", &fname, "root", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &fname);
        let rep = cat.add_replica("DE-T1-DISK", &key, ReplicaState::Available, None).unwrap();
        d.ctx.fleet.get("DE-T1-DISK").unwrap().put(&rep.pfn, bytes, now).unwrap();
        cat.attach(&ds, &key).unwrap();
        files.push(key);
    }
    cat.close(&ds).unwrap();
    // origin pin: the reaper must not garbage-collect the only source
    cat.add_rule(RuleSpec::new("root", ds.clone(), "DE-T1-DISK", 1)).unwrap();
    (ds, files)
}

/// Three read bursts against the viral dataset inside one day.
fn crowd() -> Scenario {
    let burst = |accesses| Event::FlashCrowd {
        scope: "data18".into(),
        name: "viral.ds".into(),
        accesses,
    };
    Scenario::new("flash crowd")
        .at_hours(2, burst(30))
        .at_hours(5, burst(30))
        .at_hours(8, burst(30))
}

#[test]
fn flash_crowd_caches_are_created_then_reaped() {
    let mut d = placement_driver(2001);
    let (ds, files) = seed_viral_dataset(&d);
    d.run_days(1, TICK); // warm steady state
    d.schedule_scenario(&crowd());
    d.run_days(1, TICK); // the crowd day

    let cat = d.ctx.catalog.clone();
    let caches: Vec<_> = cat.rules.scan(|r| r.activity == CACHE_ACTIVITY && r.did == ds);
    assert!(!caches.is_empty(), "heat must trigger a cache placement during the crowd");
    assert!(caches.iter().all(|r| r.expires_at.is_some()), "caches always expire");
    assert_ne!(caches[0].rse_expression, "DE-T1-DISK", "cache lands off the origin");
    assert!(cat.metrics.counter("c3po.placements") >= 1);

    // the crowd passes: heat decays, rules expire, the reaper reclaims
    d.run_days(3, TICK);
    assert_no_violations(&d);
    assert!(
        cat.rules.scan(|r| r.activity == CACHE_ACTIVITY && r.did == ds).is_empty(),
        "cache rules reaped after the crowd"
    );
    for f in &files {
        let extra: Vec<String> = cat
            .available_replicas(f)
            .into_iter()
            .map(|r| r.rse)
            .filter(|rse| rse != "DE-T1-DISK")
            .collect();
        assert!(extra.is_empty(), "cache copies of {f} reclaimed, found {extra:?}");
    }
}

#[test]
fn flagged_rse_decommissions_to_done() {
    let seed = 2002u64;
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "1h");
    cfg.set("heartbeat", "ttl", "45m");
    // quiet grid: only the seeded data, so the drain can finish fully
    let mut d = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 0,
            files_per_dataset: 1,
            derivations_per_day: 0,
            analysis_accesses_per_day: 0,
            seed: seed ^ 0xA0D,
            ..Default::default()
        },
        cfg,
    );
    d.enable_invariant_checks(2 * HOUR_MS);
    let cat = d.ctx.catalog.clone();
    let now = cat.now();
    let mut keys = Vec::new();
    for i in 0..2 {
        let name = format!("decom.f{i}");
        let bytes = 20_000_000u64;
        let adler = synthetic_adler32_for(&name, bytes);
        cat.add_file("data18", &name, "root", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        let rep = cat.add_replica("CA-T2-1", &key, ReplicaState::Available, None).unwrap();
        d.ctx.fleet.get("CA-T2-1").unwrap().put(&rep.pfn, bytes, now).unwrap();
        cat.add_rule(RuleSpec::new("root", key.clone(), "CA-T2-1|DE-T1-DISK", 1)).unwrap();
        keys.push(key);
    }
    cat.set_rse_attribute("CA-T2-1", "decommission", "pending").unwrap();
    d.run_days(2, TICK);

    assert_no_violations(&d);
    let rse = cat.get_rse("CA-T2-1").unwrap();
    assert_eq!(rse.attr("decommission"), Some("done"));
    assert!(!rse.availability_write, "decommissioned RSE refuses writes");
    let mut locks_left = 0;
    cat.locks.for_each(|l| {
        if l.rse == "CA-T2-1" {
            locks_left += 1;
        }
    });
    assert_eq!(locks_left, 0, "nothing pins the decommissioned RSE");
    for key in &keys {
        assert!(
            cat.available_replicas(key).iter().any(|r| r.rse == "DE-T1-DISK"),
            "{key} moved off the decommissioned RSE"
        );
    }
    assert_eq!(cat.metrics.counter("bb8.decommissions"), 1);
    assert_eq!(cat.metrics.counter("bb8.decommissions_completed"), 1);
}

#[test]
fn placement_runs_are_deterministic_for_a_fixed_seed() {
    let run = |seed: u64| {
        let mut d = placement_driver(seed);
        seed_viral_dataset(&d);
        d.run_days(1, TICK);
        d.schedule_scenario(&crowd());
        d.run_days(2, TICK);
        assert_no_violations(&d);
        let placements = d.ctx.catalog.metrics.counter("c3po.placements");
        (d.days, placements)
    };
    let a = run(4321);
    let b = run(4321);
    assert_eq!(a, b, "fixed seed must reproduce identical placement runs");
    assert!(a.1 >= 1, "the crowd produced at least one placement");
}
