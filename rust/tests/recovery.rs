//! Crash-recovery end-to-end suite: the durable catalog (WAL + sharded
//! snapshots) must make a process crash a routine restart, not data
//! loss.
//!
//! * property: for random catalog mutation streams (files, datasets,
//!   metadata, replicas, rules, transfer outcomes, erasures) with
//!   checkpoints at arbitrary points, `Catalog::open_with` yields a
//!   catalog *observationally equal* to the never-crashed one — ordered
//!   scans of every table plus every secondary/multi index read;
//! * a torn WAL tail (crash mid-write) drops exactly the torn commit —
//!   never half of one;
//! * the `ProcessCrash` chaos scenario drops the live catalog mid-run,
//!   recovers from disk, and the full `sim::invariants` suite plus the
//!   ongoing workload keep passing;
//! * registry row counters and `add_multi_index` back-fill behave on
//!   recovered tables (regression guards);
//! * paged mode (spill-to-disk under `[db] memory_budget`): crashes at
//!   arbitrary WAL cut points mid-incremental-checkpoint-cycle and
//!   mid-compaction recover to a commit prefix / fold boundary, and a
//!   budget-bounded catalog is observationally equal to an unbounded
//!   one fed identical ops;
//! * driver housekeeping purges expired auth tokens during a sim run.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::common::proptest::forall;
use rucio::core::metaexpr::MetaValue;
use rucio::core::rse::Rse;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{AuthType, Did, DidKey, RequestState, RuleState};
use rucio::core::Catalog;
use rucio::db::{Durable, MultiIndex, Table};
use rucio::jsonx::Json;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::scenario::{Event, Scenario};
use rucio::sim::workload::WorkloadSpec;

// ---------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------

fn tmpdir(name: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let i = N.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir()
        .join(format!("rucio-recovery-{}-{name}-{i}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn durable_cfg(dir: &Path) -> Config {
    let mut cfg = Config::new();
    cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
    cfg
}

fn table_json<V: Durable>(t: &Table<V>) -> Vec<Json> {
    t.scan(|_| true).iter().map(|r| r.row_to_json()).collect()
}

fn assert_table_eq<V: Durable>(name: &str, a: &Table<V>, b: &Table<V>) {
    assert_eq!(a.len(), b.len(), "table {name}: row count diverged");
    assert_eq!(table_json(a), table_json(b), "table {name}: ordered rows diverged");
}

/// Observational equality: every table's ordered contents plus every
/// secondary/multi index read must agree.
fn assert_catalogs_equal(a: &Catalog, b: &Catalog) {
    assert_table_eq("accounts", &a.accounts, &b.accounts);
    assert_table_eq("identities", &a.identities, &b.identities);
    assert_table_eq("tokens", &a.tokens, &b.tokens);
    assert_table_eq("scopes", &a.scopes, &b.scopes);
    assert_table_eq("dids", &a.dids, &b.dids);
    assert_table_eq("attachments", &a.attachments, &b.attachments);
    assert_table_eq("name_tombstones", &a.name_tombstones, &b.name_tombstones);
    assert_table_eq("rses", &a.rses, &b.rses);
    assert_table_eq("distances", &a.distances, &b.distances);
    assert_table_eq("replicas", &a.replicas, &b.replicas);
    assert_table_eq("bad_replicas", &a.bad_replicas, &b.bad_replicas);
    assert_table_eq("rules", &a.rules, &b.rules);
    assert_table_eq("locks", &a.locks, &b.locks);
    assert_table_eq("requests", &a.requests, &b.requests);
    assert_table_eq("account_limits", &a.limits, &b.limits);
    assert_table_eq("account_usage", &a.usages, &b.usages);
    assert_table_eq("subscriptions", &a.subscriptions, &b.subscriptions);
    assert_table_eq("outbox", &a.outbox, &b.outbox);
    assert_table_eq("popularity", &a.popularity, &b.popularity);

    // registry counters agree table by table
    assert_eq!(a.registry.snapshot(), b.registry.snapshot(), "registry snapshots");

    // secondary indexes: equality of reads
    for st in [RuleState::Ok, RuleState::Replicating, RuleState::Stuck, RuleState::Suspended] {
        assert_eq!(a.rules_by_state.get(&st), b.rules_by_state.get(&st), "rules_by_state {st:?}");
    }
    for st in RequestState::ALL {
        assert_eq!(
            a.requests_by_state.get(&st),
            b.requests_by_state.get(&st),
            "requests_by_state {st:?}"
        );
    }
    assert_eq!(
        a.requests_by_dest.index_keys(),
        b.requests_by_dest.index_keys(),
        "requests_by_dest keys"
    );
    assert_eq!(a.dids_by_scope.index_keys(), b.dids_by_scope.index_keys());
    for scope in a.dids_by_scope.index_keys() {
        assert_eq!(
            a.dids_by_scope.get(&scope),
            b.dids_by_scope.get(&scope),
            "dids_by_scope {scope}"
        );
    }
    assert_eq!(a.dids_by_expiry.index_keys(), b.dids_by_expiry.index_keys());
    assert_eq!(a.att_by_parent.index_keys(), b.att_by_parent.index_keys());
    assert_eq!(a.att_by_child.index_keys(), b.att_by_child.index_keys());
    assert_eq!(a.replicas_by_did.index_keys(), b.replicas_by_did.index_keys());
    for k in a.replicas_by_did.index_keys() {
        assert_eq!(a.replicas_by_did.get(&k), b.replicas_by_did.get(&k), "replicas_by_did {k}");
    }
    assert_eq!(
        a.replicas_by_tombstone.index_keys(),
        b.replicas_by_tombstone.index_keys(),
        "reaper work queue"
    );
    assert_eq!(a.locks_by_rule.index_keys(), b.locks_by_rule.index_keys());
    for k in a.locks_by_rule.index_keys() {
        assert_eq!(a.locks_by_rule.get(&k), b.locks_by_rule.get(&k), "locks_by_rule {k}");
    }
    assert_eq!(a.locks_by_did.index_keys(), b.locks_by_did.index_keys());
    assert_eq!(a.locks_by_replica.index_keys(), b.locks_by_replica.index_keys());
    assert_eq!(a.rules_by_did.index_keys(), b.rules_by_did.index_keys());
    assert_eq!(a.rules_by_expiry.index_keys(), b.rules_by_expiry.index_keys());
    // the PR 3 inverted metadata index, postings and counts
    assert_eq!(a.meta_index.key_counts(), b.meta_index.key_counts(), "meta_index postings");
}

/// Seed a durable catalog with two RSEs and a scope.
fn seeded(dir: &Path, extra: impl FnOnce(&mut Config)) -> Catalog {
    let mut cfg = durable_cfg(dir);
    extra(&mut cfg);
    let c = Catalog::new(Clock::sim_at(1_600_000_000_000), cfg);
    c.add_scope("s", "root").unwrap();
    let now = c.now();
    c.add_rse(Rse::new("A", now).with_attr("site", "A")).unwrap();
    c.add_rse(Rse::new("B", now).with_attr("site", "B")).unwrap();
    c
}

// ---------------------------------------------------------------------
// the recovery-equivalence property
// ---------------------------------------------------------------------

#[test]
fn prop_recovered_catalog_equals_live() {
    forall(8, |g| {
        let dir = tmpdir("prop");
        let group = g.bool();
        let shards = g.usize(1, 7);
        let live = seeded(&dir, |cfg| {
            cfg.set("db", "shards", shards.to_string());
            cfg.set("db", "group_commit", if group { "true" } else { "false" });
        });
        let meta_keys = ["run", "datatype", "eff", "flag"];
        let meta_vals = ["358031", "RAW", "0.35", "true", "data18_13TeV", "-7"];
        let mut files: Vec<DidKey> = Vec::new();
        let mut datasets: Vec<DidKey> = Vec::new();
        for step in 0..g.usize(40, 120) {
            // upper bound exclusive: 0..=9, so the `_` arm (checkpoint)
            // fires on 9
            match g.usize(0, 10) {
                0 | 1 => {
                    let name = format!("f{step}");
                    live.add_file("s", &name, "root", g.u64(1, 1_000_000), "aabbccdd", None)
                        .unwrap();
                    files.push(DidKey::new("s", &name));
                }
                2 => {
                    let name = format!("ds{step}");
                    live.add_dataset("s", &name, "root").unwrap();
                    let ds = DidKey::new("s", &name);
                    for _ in 0..g.usize(0, 3) {
                        if let Some(f) = pick(g, &files) {
                            let _ = live.attach(&ds, &f);
                        }
                    }
                    datasets.push(ds);
                }
                3 => {
                    if let Some(f) = pick(g, &files) {
                        let key = *g.pick(&meta_keys);
                        let val = *g.pick(&meta_vals);
                        let _ = live.set_metadata(&f, key, val);
                    }
                }
                4 => {
                    if let Some(f) = pick(g, &files) {
                        let rse = if g.bool() { "A" } else { "B" };
                        let _ = live.add_replica(
                            rse,
                            &f,
                            rucio::core::types::ReplicaState::Available,
                            None,
                        );
                    }
                }
                5 => {
                    let target = if g.bool() { pick(g, &files) } else { pick(g, &datasets) };
                    if let Some(did) = target {
                        let rse = if g.bool() { "A" } else { "B" };
                        let _ = live.add_rule(RuleSpec::new("root", did, rse, 1));
                    }
                }
                6 => {
                    let reqs = live.requests.keys();
                    if !reqs.is_empty() {
                        let id = reqs[g.usize(0, reqs.len())];
                        if g.bool() {
                            let _ = live.on_transfer_done(id);
                        } else {
                            let _ = live.on_transfer_failed(id, "simulated failure");
                        }
                    }
                }
                7 => {
                    let rules = live.rules.keys();
                    if !rules.is_empty() {
                        let _ = live.delete_rule(rules[g.usize(0, rules.len())]);
                    }
                }
                8 => {
                    if let Some(f) = pick(g, &files) {
                        let _ = live.erase_did(&f);
                    }
                }
                _ => {
                    if g.chance(0.5) {
                        live.checkpoint_all().unwrap();
                    }
                }
            }
        }
        // crash at an arbitrary point in the checkpoint cycle, then
        // cold-boot from disk and compare against the survivor
        let recovered = Catalog::open_with(
            Clock::sim_at(live.now()),
            live.cfg.clone(),
        )
        .unwrap();
        assert_catalogs_equal(&live, &recovered);
        std::fs::remove_dir_all(&dir).ok();
    });
}

fn pick(g: &mut rucio::common::proptest::Gen, keys: &[DidKey]) -> Option<DidKey> {
    if keys.is_empty() {
        None
    } else {
        Some(keys[g.usize(0, keys.len())].clone())
    }
}

// ---------------------------------------------------------------------
// torn WAL tail: the final record dies whole
// ---------------------------------------------------------------------

#[test]
fn torn_did_wal_tail_is_discarded_never_half_applied() {
    let dir = tmpdir("torn");
    let live = seeded(&dir, |_| {});
    for i in 0..5 {
        live.add_file("s", &format!("f{i}"), "root", 10, "aabbccdd", None).unwrap();
        live.set_metadata(&DidKey::new("s", &format!("f{i}")), "run", &format!("{i}"))
            .unwrap();
    }
    // crash mid-write: the last dids.wal frame (f4's metadata update)
    // loses its final byte
    let wal_path = dir.join("dids.wal");
    let len = std::fs::metadata(&wal_path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap();
    f.set_len(len - 1).unwrap();
    drop(f);

    let recovered = Catalog::open_with(Clock::sim_at(live.now()), live.cfg.clone()).unwrap();
    assert_eq!(recovered.dids.len(), 5, "all five files survive (inserts are older frames)");
    // f4 exists but its metadata update — the torn frame — is gone whole
    let f4 = recovered.get_did(&DidKey::new("s", "f4")).unwrap();
    assert!(f4.meta.is_empty(), "torn metadata commit discarded, not half-applied");
    let f3 = recovered.get_did(&DidKey::new("s", "f3")).unwrap();
    assert_eq!(f3.meta.get("run"), Some(&MetaValue::Int(3)), "intact frames replayed");
    // the inverted index agrees with the recovered rows, not the lost one
    let postings = recovered.meta_index.key_counts();
    assert_eq!(postings.len(), 4, "four run postings: {postings:?}");
    assert_eq!(recovered.metrics.counter("db.recovery_torn_tails"), 1);
    // and the recovered catalog keeps appending cleanly after the cut
    recovered
        .set_metadata(&DidKey::new("s", "f4"), "run", "4")
        .unwrap();
    let again = Catalog::open_with(Clock::sim_at(recovered.now()), recovered.cfg.clone()).unwrap();
    assert_eq!(again.meta_index.key_counts().len(), 5);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// satellite regression guards
// ---------------------------------------------------------------------

#[test]
fn registry_snapshot_agrees_with_table_lens_after_recovery() {
    let dir = tmpdir("registry");
    let live = seeded(&dir, |_| {});
    for i in 0..12 {
        live.add_file("s", &format!("f{i}"), "root", 10, "aabbccdd", None).unwrap();
        live.add_replica("A", &DidKey::new("s", &format!("f{i}")),
            rucio::core::types::ReplicaState::Available, None).unwrap();
    }
    live.add_rule(RuleSpec::new("root", DidKey::new("s", "f0"), "B", 1)).unwrap();
    live.checkpoint_all().unwrap();
    live.add_file("s", "post-ckpt", "root", 1, "x", None).unwrap();

    let recovered = Catalog::open_with(Clock::sim_at(live.now()), live.cfg.clone()).unwrap();
    // the O(1) counters behind Registry::snapshot must equal actual row
    // counts after a cold boot
    let snap = recovered.registry.snapshot();
    assert_eq!(snap["dids"], recovered.dids.keys().len());
    assert_eq!(snap["replicas"], recovered.replicas.keys().len());
    assert_eq!(snap["rules"], recovered.rules.keys().len());
    assert_eq!(snap["requests"], recovered.requests.keys().len());
    assert_eq!(snap["dids"], 13);
    assert_eq!(snap, live.registry.snapshot(), "recovered counters match the live catalog");
    // sim::invariants' counter-agreement check concurs
    let violations = rucio::sim::invariants::check(&recovered);
    assert!(violations.is_empty(), "{violations:?}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_index_backfill_on_recovered_table() {
    let dir = tmpdir("backfill");
    let live = seeded(&dir, |_| {});
    for i in 0..6 {
        let key = DidKey::new("s", &format!("f{i}"));
        live.add_file("s", &format!("f{i}"), "root", 10, "aabbccdd", None).unwrap();
        live.set_metadata(&key, "datatype", if i % 2 == 0 { "RAW" } else { "AOD" }).unwrap();
    }
    live.erase_did(&DidKey::new("s", "f5")).unwrap();
    live.checkpoint_all().unwrap();

    let recovered = Catalog::open_with(Clock::sim_at(live.now()), live.cfg.clone()).unwrap();
    // a brand-new multi index attached to the *recovered* table must
    // back-fill to exactly the built-in one (the PR 3 erase-did postings
    // fix must survive a restart: f5's postings are gone)
    let fresh: MultiIndex<Did, (String, String, MetaValue)> = MultiIndex::new(|d: &Did| {
        d.meta
            .iter()
            .map(|(k, v)| (d.key.scope.clone(), k.clone(), v.clone()))
            .collect()
    });
    recovered.dids.add_multi_index(&fresh).unwrap();
    assert_eq!(fresh.key_counts(), recovered.meta_index.key_counts());
    assert_eq!(fresh.len(), 5, "erased DID's postings stayed erased across the restart");
    // and the back-filled index stays live for post-recovery mutations
    recovered.erase_did(&DidKey::new("s", "f4")).unwrap();
    assert_eq!(fresh.key_counts(), recovered.meta_index.key_counts());
    assert_eq!(fresh.len(), 4);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// paged mode: spill-to-disk, incremental checkpoints, WAL compaction
// ---------------------------------------------------------------------

/// Crash mid-incremental-checkpoint cycle. With paged mode on, shard
/// spill files are routinely *newer* than the manifest's fence —
/// evictions rewrite them between checkpoints. Cutting the dids WAL at
/// an arbitrary byte at or past the last maintenance point must
/// recover exactly a commit-prefix state: the newer shard images plus
/// idempotent full-row replay can neither invent nor lose a commit.
#[test]
fn prop_crash_mid_incremental_checkpoint_recovers_a_commit_prefix() {
    forall(10, |g| {
        let dir = tmpdir("incr");
        let live = seeded(&dir, |cfg| {
            cfg.set("db", "shards", "4");
            cfg.set("db", "memory_budget", "6");
        });
        let wal_bytes = || live.registry.wal_stats()["dids"].bytes;
        // dids states at commit granularity; `floor` tracks the WAL
        // length at the last maintenance op — spill files on disk only
        // reflect commits at or before it, so cuts at or past the
        // floor keep "recovered == some commit prefix" exact.
        let mut states: Vec<Vec<Json>> = vec![table_json(&live.dids)];
        let mut names: Vec<String> = Vec::new();
        let mut floor = 0u64;
        for step in 0..g.usize(15, 60) {
            match g.usize(0, 6) {
                0 | 1 | 2 => {
                    let name = format!("f{step}");
                    live.add_file("s", &name, "root", 10, "aabbccdd", None).unwrap();
                    names.push(name);
                    states.push(table_json(&live.dids));
                }
                3 => {
                    if !names.is_empty() {
                        let name = names[g.usize(0, names.len())].clone();
                        live.set_metadata(&DidKey::new("s", &name), "run", "358031").unwrap();
                        states.push(table_json(&live.dids));
                    }
                }
                4 => {
                    // incremental checkpoint: only dirty shards rewrite
                    live.checkpoint_all().unwrap();
                    floor = wal_bytes();
                }
                _ => {
                    // evictions write shard files newer than the fence
                    live.enforce_memory_budgets();
                    floor = wal_bytes();
                }
            }
        }
        // crash: cut the dids WAL at an arbitrary byte past the floor
        let wal_path = dir.join("dids.wal");
        let len = std::fs::metadata(&wal_path).unwrap().len();
        if len > floor {
            let cut = g.u64(floor, len + 1);
            std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap().set_len(cut).unwrap();
        }
        let recovered = Catalog::open_with(Clock::sim_at(live.now()), live.cfg.clone()).unwrap();
        let got = table_json(&recovered.dids);
        assert!(states.contains(&got), "recovered dids must equal a commit prefix");
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// Crash mid-compaction. After the WAL is folded down to
/// `[barrier][one commit]`, an arbitrary-byte cut must recover to
/// either the snapshot-fence state or the fully-folded final state —
/// the fold collapses intermediate states by design, but must never
/// *expose* one (or half a folded commit).
#[test]
fn prop_crash_mid_compaction_recovers_a_fold_boundary() {
    forall(12, |g| {
        let dir = tmpdir("fold");
        let live = seeded(&dir, |_| {});
        let limits =
            |c: &Catalog| (c.get_account_limit("root", "A"), c.get_account_limit("root", "B"));
        // optionally fence some early churn behind a checkpoint
        let mut fenced = (None, None);
        if g.chance(0.6) {
            for i in 0..g.u64(1, 20) {
                live.set_account_limit("root", "A", i).unwrap();
            }
            live.checkpoint_all().unwrap();
            fenced = limits(&live);
        }
        for _ in 0..g.usize(10, 60) {
            let rse = if g.bool() { "A" } else { "B" };
            live.set_account_limit("root", rse, g.u64(0, 1_000_000)).unwrap();
        }
        let final_state = limits(&live);
        let folds = live.compact_wals(0);
        let cs = &folds["account_limits"];
        assert!(cs.records_after <= 2, "fold leaves at most barrier + one commit: {cs:?}");
        assert!(cs.ops_dropped > 0, "overwrite churn folded away: {cs:?}");
        // crash at an arbitrary byte of the folded log
        let wal_path = dir.join("account_limits.wal");
        let len = std::fs::metadata(&wal_path).unwrap().len();
        let cut = g.u64(0, len + 1);
        std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap().set_len(cut).unwrap();
        let recovered = Catalog::open_with(Clock::sim_at(live.now()), live.cfg.clone()).unwrap();
        let got = limits(&recovered);
        assert!(
            got == final_state || got == fenced,
            "recovered {got:?} must be the fence {fenced:?} or the fold {final_state:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// Spill ≡ memory: a paged catalog under an aggressive hot-row budget,
/// with maintenance (incremental checkpoints + evictions) interleaved
/// into the op stream, is observationally equal to an unbounded
/// catalog fed the identical ops — and so is its cold-booted recovery.
#[test]
fn prop_paged_catalog_equals_unbounded_catalog() {
    forall(6, |g| {
        let dir_p = tmpdir("paged");
        let dir_u = tmpdir("unbounded");
        let paged = seeded(&dir_p, |cfg| {
            cfg.set("db", "shards", "4");
            cfg.set("db", "memory_budget", "5");
        });
        let plain = seeded(&dir_u, |cfg| cfg.set("db", "shards", "4"));
        let mut files: Vec<DidKey> = Vec::new();
        for step in 0..g.usize(30, 90) {
            match g.usize(0, 8) {
                0 | 1 | 2 => {
                    let name = format!("f{step}");
                    let size = g.u64(1, 1_000_000);
                    paged.add_file("s", &name, "root", size, "aabbccdd", None).unwrap();
                    plain.add_file("s", &name, "root", size, "aabbccdd", None).unwrap();
                    files.push(DidKey::new("s", &name));
                }
                3 => {
                    if let Some(f) = pick(g, &files) {
                        let rp = paged.set_metadata(&f, "run", "358031").is_ok();
                        let ru = plain.set_metadata(&f, "run", "358031").is_ok();
                        assert_eq!(rp, ru, "set_metadata outcome diverged");
                    }
                }
                4 => {
                    if let Some(f) = pick(g, &files) {
                        let rse = if g.bool() { "A" } else { "B" };
                        let st = rucio::core::types::ReplicaState::Available;
                        let rp = paged.add_replica(rse, &f, st, None).is_ok();
                        let ru = plain.add_replica(rse, &f, st, None).is_ok();
                        assert_eq!(rp, ru, "add_replica outcome diverged");
                    }
                }
                5 => {
                    if let Some(f) = pick(g, &files) {
                        let rse = if g.bool() { "A" } else { "B" };
                        let rp = paged.add_rule(RuleSpec::new("root", f.clone(), rse, 1)).is_ok();
                        let ru = plain.add_rule(RuleSpec::new("root", f, rse, 1)).is_ok();
                        assert_eq!(rp, ru, "add_rule outcome diverged");
                    }
                }
                6 => {
                    if let Some(f) = pick(g, &files) {
                        let rp = paged.erase_did(&f).is_ok();
                        let ru = plain.erase_did(&f).is_ok();
                        assert_eq!(rp, ru, "erase_did outcome diverged");
                    }
                }
                _ => {
                    // maintenance on the paged side only: it must never
                    // change what readers observe
                    if g.bool() {
                        paged.checkpoint_all().unwrap();
                    }
                    paged.enforce_memory_budgets();
                }
            }
        }
        paged.enforce_memory_budgets();
        assert_catalogs_equal(&paged, &plain);
        // the budget actually bounds every table's hot set
        let spill = paged.registry.spill();
        for (name, s) in &spill {
            assert!(s.hot_rows <= s.budget, "table {name} over budget after enforcement: {s:?}");
        }
        assert!(
            spill.values().any(|s| s.evictions > 0),
            "the property must exercise eviction: {spill:?}"
        );
        // cold boot of the paged catalog matches too
        let recovered = Catalog::open_with(Clock::sim_at(paged.now()), paged.cfg.clone()).unwrap();
        assert_catalogs_equal(&recovered, &plain);
        std::fs::remove_dir_all(&dir_p).ok();
        std::fs::remove_dir_all(&dir_u).ok();
    });
}

// ---------------------------------------------------------------------
// chaos: ProcessCrash mid-run + full invariant suite
// ---------------------------------------------------------------------

#[test]
fn process_crash_chaos_recovers_and_invariants_hold() {
    let dir = tmpdir("chaos");
    let seed = 20_260_731;
    let mut cfg = durable_cfg(&dir);
    cfg.set("db", "checkpoint_interval", "2h");
    cfg.set("reaper", "tombstone_grace", "1h");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 4,
            files_per_dataset: 4,
            median_file_bytes: 500_000_000,
            derivations_per_day: 3,
            analysis_accesses_per_day: 40,
            seed: seed ^ 0xA0D,
            ..Default::default()
        },
        cfg,
    );
    assert!(driver.ctx.catalog.durable());
    driver.enable_invariant_checks(4 * 60 * MINUTE_MS);
    // an outage brackets the crash so recovery happens under live churn
    let sc = Scenario::new("crash mid-run")
        .at_hours(6, Event::RseDown { rse: "CA-T2-1".into() })
        .at_hours(30, Event::ProcessCrash)
        .at_hours(40, Event::RseUp { rse: "CA-T2-1".into() });
    driver.schedule_scenario(&sc);
    driver.run_days(2, 10 * MINUTE_MS);

    assert_eq!(driver.process_crashes, 1, "the catalog was dropped and recovered");
    assert!(driver.violations.is_empty(), "{:?}", driver.violations);
    // the recovered catalog carried real state across the crash...
    let cat = &driver.ctx.catalog;
    assert!(cat.metrics.gauge("db.recovered_rows") > 0, "snapshot had rows");
    assert!(!cat.dids.is_empty(), "namespace survived");
    // ...and the system kept operating afterwards (crash was at hour 30)
    assert!(driver.days[1].transfers_done > 0, "day 2 transfers: {:?}", driver.days[1]);
    assert!(
        cat.metrics.counter("checkpointer.runs") > 0,
        "checkpointer kept snapshotting after recovery"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn process_crash_without_durability_is_a_noop() {
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, ..Default::default() },
        WorkloadSpec::default(),
        Config::new(),
    );
    assert!(!driver.process_crash_and_recover());
    assert_eq!(driver.process_crashes, 0);
    assert!(driver.violations.is_empty());
}

// ---------------------------------------------------------------------
// housekeeping: expired tokens vanish during a sim run
// ---------------------------------------------------------------------

#[test]
fn expired_tokens_are_purged_during_a_sim_run() {
    let mut cfg = Config::new();
    cfg.set("auth", "token_lifetime", "30m");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 2,
            files_per_dataset: 2,
            ..Default::default()
        },
        cfg,
    );
    let cat = driver.ctx.catalog.clone();
    cat.add_identity("operator", AuthType::UserPass, "root", Some("hunter2")).unwrap();
    let token = cat.auth_userpass("root", "operator", "hunter2").unwrap();
    assert!(cat.validate_token(&token.token).is_ok());
    assert_eq!(cat.tokens.len(), 1);

    driver.run_days(1, 10 * MINUTE_MS);

    assert_eq!(cat.tokens.len(), 0, "housekeeping purged the expired token");
    assert!(cat.metrics.counter("housekeeping.tokens_purged") >= 1);
    assert!(cat.validate_token(&token.token).is_err());
}
