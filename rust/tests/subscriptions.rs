//! End-to-end subscription lifecycle (paper §2.5): register a standing
//! subscription with a `meta-expr` filter → the workload registers new
//! datasets with typed metadata → hermes publishes the `did-created`
//! events → the transmogrifier consumes them in batches and creates the
//! subscribed rules through the bulk rule path → locks and transfer
//! requests exist. Non-matching DIDs stay untouched; disabled
//! subscriptions are skipped; a fixed seed reproduces identical rule
//! counts.

use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::metaexpr::MetaValue;
use rucio::core::subscriptions::{SubscriptionFilter, SubscriptionRule};
use rucio::core::types::{DidKey, ReplicaState, RequestState};
use rucio::daemons::hermes::Hermes;
use rucio::daemons::transmogrifier::Transmogrifier;
use rucio::daemons::Daemon;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::sim::workload::WorkloadSpec;
use rucio::storagesim::synthetic_adler32_for;

/// Register a closed RAW dataset with files + T0 replicas + typed
/// metadata — what the detector workload produces.
fn add_raw_dataset(
    cat: &rucio::core::Catalog,
    name: &str,
    datatype: &str,
    stream: &str,
    run: i64,
    n_files: usize,
) -> DidKey {
    cat.add_dataset("data18", name, "tzero").unwrap();
    let ds = DidKey::new("data18", name);
    for i in 0..n_files {
        let fname = format!("{name}.f{i:04}");
        let bytes = 1_000_000 + i as u64;
        cat.add_file("data18", &fname, "tzero", bytes, &synthetic_adler32_for(&fname, bytes), None)
            .unwrap();
        let key = DidKey::new("data18", &fname);
        cat.add_replica("CERN-PROD", &key, ReplicaState::Available, None).unwrap();
        cat.attach(&ds, &key).unwrap();
    }
    cat.close(&ds).unwrap();
    cat.set_metadata_bulk(
        &ds,
        vec![
            ("datatype".into(), MetaValue::Str(datatype.into())),
            ("stream".into(), MetaValue::Str(stream.into())),
            ("run".into(), MetaValue::Int(run)),
        ],
    )
    .unwrap();
    ds
}

#[test]
fn subscription_lifecycle_end_to_end() {
    let ctx = build_grid(&GridSpec::default(), Clock::sim_at(1_600_000_000_000), Config::new());
    let cat = ctx.catalog.clone();

    // Quiet the grid's built-in RAW archival subscription so every rule
    // observed below belongs to the subscription under test.
    for sub in cat.subscriptions.scan(|_| true) {
        cat.set_subscription_enabled(sub.id, false).unwrap();
    }

    let sub_id = cat
        .add_subscription(
            "main-stream-to-t1",
            "prod",
            SubscriptionFilter {
                scopes: vec!["data18".into()],
                did_types: vec![],
                expr: Some(
                    rucio::core::metaexpr::parse(
                        "datatype=RAW AND stream=physics_Main AND run>=358000",
                    )
                    .unwrap(),
                ),
            },
            vec![SubscriptionRule {
                rse_expression: "tier=1&type=disk".into(),
                copies: 1,
                lifetime_ms: None,
                activity: "T0 Export".into(),
            }],
        )
        .unwrap();

    let mut hermes = Hermes::new(ctx.clone());
    let mut trans = Transmogrifier::new(ctx.clone(), "t1");

    // The workload registers datasets: two matching, two not.
    let match_a = add_raw_dataset(&cat, "raw.run358001", "RAW", "physics_Main", 358_001, 3);
    let match_b = add_raw_dataset(&cat, "raw.run358002", "RAW", "physics_Main", 358_002, 2);
    let miss_stream = add_raw_dataset(&cat, "raw.run358003", "RAW", "express_express", 358_003, 2);
    let miss_type = add_raw_dataset(&cat, "aod.merge01", "AOD", "physics_Main", 358_004, 2);

    // events flow: outbox → broker → transmogrifier batch
    hermes.tick(cat.now());
    let created = trans.tick(cat.now());
    assert_eq!(created, 2, "exactly the two matching datasets spawn rules");

    // rules exist, tagged with the subscription, locks + transfers applied
    for (ds, n_files) in [(&match_a, 3u32), (&match_b, 2u32)] {
        let rules = cat.list_rules_for_did(ds);
        assert_eq!(rules.len(), 1, "{ds} has its subscription rule");
        let rule = &rules[0];
        assert_eq!(rule.subscription_id, Some(sub_id));
        assert_eq!(rule.account, "prod");
        assert_eq!(rule.activity, "T0 Export");
        let locks = cat.locks_by_rule.get(&rule.id);
        assert_eq!(locks.len() as u32, n_files, "one lock per file per copy");
        assert_eq!(
            rule.locks_ok + rule.locks_replicating + rule.locks_stuck,
            n_files,
            "lock tallies cover the dataset"
        );
    }
    // the data has to move: transfer requests queued toward the T1s
    assert!(cat.requests_by_state.count(&RequestState::Queued) >= 5);

    // non-matching DIDs are untouched
    assert!(cat.list_rules_for_did(&miss_stream).is_empty());
    assert!(cat.list_rules_for_did(&miss_type).is_empty());

    // the subscription counted its matches
    let sub = cat.subscriptions.get(&sub_id).unwrap();
    assert_eq!(sub.matched, 2);

    // idempotency: replaying the same DIDs creates nothing new
    assert!(cat.match_subscriptions(&match_a).unwrap().is_empty());
    let rules_before = cat.rules.len();
    hermes.tick(cat.now());
    trans.tick(cat.now());
    assert_eq!(cat.rules.len(), rules_before);

    // disabled subscriptions are skipped...
    cat.set_subscription_enabled(sub_id, false).unwrap();
    let while_disabled =
        add_raw_dataset(&cat, "raw.run358005", "RAW", "physics_Main", 358_005, 2);
    hermes.tick(cat.now());
    assert_eq!(trans.tick(cat.now()), 0);
    assert!(cat.list_rules_for_did(&while_disabled).is_empty());

    // ...and re-enabling matches new events only (the old ones were
    // consumed; the asynchronous contract is at-least-once via replay,
    // which match_subscriptions covers interactively)
    cat.set_subscription_enabled(sub_id, true).unwrap();
    let after_reenable =
        add_raw_dataset(&cat, "raw.run358006", "RAW", "physics_Main", 358_006, 2);
    hermes.tick(cat.now());
    assert_eq!(trans.tick(cat.now()), 1);
    assert_eq!(cat.list_rules_for_did(&after_reenable).len(), 1);
}

/// Acceptance: a fixed-seed sim run with subscriptions enabled
/// reproduces identical rule counts (and identical per-day stats).
#[test]
fn fixed_seed_run_reproduces_identical_rule_counts() {
    let run = || {
        let mut driver = standard_driver(
            &GridSpec { t2_per_region: 1, ..Default::default() },
            WorkloadSpec {
                raw_datasets_per_day: 4,
                files_per_dataset: 3,
                derivations_per_day: 2,
                analysis_accesses_per_day: 20,
                discovery_queries_per_day: 12,
                ..Default::default()
            },
            Config::new(),
        );
        driver.run_days(2, 10 * MINUTE_MS);
        let cat = &driver.ctx.catalog;
        let sub_rules = cat.rules.count_where(|r| r.subscription_id.is_some());
        (
            cat.rules.len(),
            sub_rules,
            cat.metrics.counter("subscriptions.rules_created"),
            driver.days.clone(),
        )
    };
    let (rules_a, sub_a, created_a, days_a) = run();
    let (rules_b, sub_b, created_b, days_b) = run();
    assert!(sub_a > 0, "the standing RAW subscription matched something");
    assert!(created_a > 0);
    assert_eq!(rules_a, rules_b, "total rule count reproduces");
    assert_eq!(sub_a, sub_b, "subscription rule count reproduces");
    assert_eq!(created_a, created_b);
    assert_eq!(days_a, days_b, "per-day stats reproduce bit-for-bit");
}
