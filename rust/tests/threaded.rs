//! The concurrent-runtime soak (ISSUE PR 6): the full standard daemon
//! fleet on real OS threads + the thread-pooled REST server + concurrent
//! clients, all against one shared durable catalog for a few wall-clock
//! seconds — then the complete `sim::invariants` suite must come back
//! clean on the quiesced catalog. Plus the heartbeat failover satellite:
//! two live instances partition work; killing one hands its shard to the
//! survivor within the TTL.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use rucio::client::RucioClient;
use rucio::common::clock::Clock;
use rucio::common::config::Config;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{AuthType, DidKey, ReplicaState};
use rucio::daemons::heartbeat::Heartbeats;
use rucio::daemons::{FleetHandle, Paced};
use rucio::db::assigned_to;
use rucio::sim::driver::Driver;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::sim::invariants;
use rucio::storagesim::synthetic_adler32_for;

/// Spin until `cond` holds or `timeout` passes; true iff it held.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

#[test]
fn threaded_soak_full_fleet_and_rest_load_end_with_clean_invariants() {
    let dir = std::env::temp_dir().join(format!("rucio-threaded-soak-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = Config::new();
    cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
    cfg.set("db", "shards", "16");
    // Real clock everywhere: daemons, HTTP, and catalog share wall time.
    let spec = GridSpec {
        t2_per_region: 1,
        fts_servers: 1,
        storage_flakiness: 0.0,
        ..GridSpec::default()
    };
    let ctx = build_grid(&spec, Clock::Real, cfg);
    ctx.catalog
        .add_identity("alice", AuthType::UserPass, "alice", Some("pw"))
        .unwrap();

    // Seed files with real bytes on T0 storage, each pinned by a
    // replication rule, so the fleet has genuine transfers to move
    // while the REST load runs.
    let now = ctx.catalog.now();
    let t0 = ctx.fleet.get("CERN-PROD").unwrap();
    for i in 0..8 {
        let name = format!("seed-{i}");
        let bytes = 1_000 + i as u64;
        let adler = synthetic_adler32_for(&name, bytes);
        ctx.catalog.add_file("data18", &name, "prod", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        let rep = ctx
            .catalog
            .add_replica("CERN-PROD", &key, ReplicaState::Available, None)
            .unwrap();
        t0.put(&rep.pfn, bytes, now).unwrap();
        ctx.catalog
            .add_rule(RuleSpec::new("prod", key, "tier=1&type=disk", 1))
            .unwrap();
    }

    let mut fleet = FleetHandle::spawn(Paced::fleet(Driver::standard_daemons(&ctx), 50));
    assert_eq!(fleet.len(), 17, "the whole standard fleet is live");
    let server = rucio::server::serve(
        ctx.catalog.clone(),
        ctx.broker.clone(),
        "127.0.0.1:0",
        4,
    )
    .unwrap();
    let url = server.url();

    // Concurrent REST clients (one per server worker): a mixed mix of
    // writes (files, replicas, rules — each a durable WAL commit) and
    // reads, racing the daemons on the shared catalog.
    let n_clients = 4;
    let per_client = 120;
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let url = url.clone();
            s.spawn(move || {
                let client = RucioClient::connect(&url, "alice", "alice", "pw").unwrap();
                for i in 0..per_client {
                    let name = format!("soak-c{c}-i{i}");
                    let prev = format!("soak-c{c}-i{}", i - (i % 5));
                    match i % 5 {
                        0 => client.add_file("data18", &name, 500, "aabbccdd").unwrap(),
                        1 => {
                            client
                                .register_replica("CERN-PROD", "data18", &prev, None)
                                .map(|_| ())
                                .unwrap();
                        }
                        2 => {
                            // unique per (c, i): no duplicate-rule races
                            client
                                .add_rule("data18", &prev, "tier=1&type=disk", 1, None)
                                .map(|_| ())
                                .unwrap();
                        }
                        3 => {
                            client.get_did("data18", &prev).map(|_| ()).unwrap();
                        }
                        _ => {
                            client.ping().map(|_| ()).unwrap();
                        }
                    }
                }
            });
        }
    });

    // Let the fleet chew on the queued transfers for a bit of wall clock.
    std::thread::sleep(Duration::from_millis(1500));
    drop(server);
    fleet.shutdown();

    // Quiesced: the full invariant suite must be clean.
    let violations = invariants::check(&ctx.catalog);
    assert!(violations.is_empty(), "invariants violated after soak: {violations:?}");
    let caps = invariants::check_fts_link_caps(&ctx);
    assert!(caps.is_empty(), "FTS link caps violated after soak: {caps:?}");

    // The run did real work: every client op landed and the contention
    // probes saw the traffic.
    let total_files = n_clients * (per_client / 5);
    assert!(
        ctx.catalog.dids.len() >= 8 + total_files,
        "all soak files registered"
    );
    assert!(ctx.catalog.rules.len() >= 8, "seed rules live");
    let contention = ctx.catalog.registry.contention();
    let locks: u64 = contention.values().map(|c| c.single_write_locks).sum();
    assert!(locks > 0, "contention probes observed the load: {contention:?}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn heartbeat_failover_hands_the_dead_shard_to_the_survivor_within_ttl() {
    const TTL_MS: i64 = 400;
    let hb = Arc::new(Heartbeats::with_ttl(TTL_MS));
    let stop_a = Arc::new(AtomicBool::new(false));
    let stop_b = Arc::new(AtomicBool::new(false));
    let a_assign = Arc::new(Mutex::new((usize::MAX, 0usize)));
    let b_assign = Arc::new(Mutex::new((usize::MAX, 0usize)));

    let spawn_beater = |instance: &'static str,
                        stop: Arc<AtomicBool>,
                        assign: Arc<Mutex<(usize, usize)>>| {
        let hb = hb.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let now = Clock::Real.now_ms();
                *assign.lock().unwrap() = hb.beat("reaper", instance, now);
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };
    let ha = spawn_beater("reaper-a", stop_a.clone(), a_assign.clone());
    let hb_thread = spawn_beater("reaper-b", stop_b.clone(), b_assign.clone());

    // Phase 1: both instances live — they agree on a 2-way split.
    assert!(
        wait_until(Duration::from_secs(5), || {
            a_assign.lock().unwrap().1 == 2 && b_assign.lock().unwrap().1 == 2
        }),
        "both instances never saw each other"
    );
    let (ia, _) = *a_assign.lock().unwrap();
    let (ib, _) = *b_assign.lock().unwrap();
    assert_ne!(ia, ib, "live instances must take distinct indexes");
    for key in 0..500u64 {
        let owners =
            [ia, ib].iter().filter(|&&w| assigned_to(key, w, 2)).count();
        assert_eq!(owners, 1, "key {key} must have exactly one owner");
    }

    // Phase 2: kill A; within the TTL the survivor owns everything.
    stop_a.store(true, Ordering::Relaxed);
    ha.join().unwrap();
    let t_kill = Instant::now();
    assert!(
        wait_until(Duration::from_secs(5), || *b_assign.lock().unwrap() == (0, 1)),
        "survivor never took over the dead instance's shard"
    );
    // TTL is 400 ms, beats every 50 ms: takeover must be prompt.
    assert!(
        t_kill.elapsed() < Duration::from_secs(3),
        "takeover exceeded the TTL horizon: {:?}",
        t_kill.elapsed()
    );
    let (ib, n) = *b_assign.lock().unwrap();
    assert_eq!((ib, n), (0, 1));
    for key in 0..500u64 {
        assert!(assigned_to(key, ib, n), "survivor owns every key");
    }
    assert_eq!(hb.live("reaper", Clock::Real.now_ms()), 1);

    stop_b.store(true, Ordering::Relaxed);
    hb_thread.join().unwrap();
}
