//! Transfer orchestration v2, end to end: the admission-controlled
//! request pipeline (throttler → conveyor → ftssim), the failure/retry
//! path, multi-hop routing when no direct link exists (with staging
//! replicas reaped afterwards), and a full chaos run combining a
//! link-saturation storm with an inter-region partition — asserting the
//! per-link cap invariant, non-starvation of a low-share activity, and
//! multi-hop convergence of a partitioned rule.

use std::sync::Arc;

use rucio::common::clock::{Clock, EpochMs, HOUR_MS, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::rse::Rse;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState, RequestState, RuleState};
use rucio::core::Catalog;
use rucio::daemons::conveyor::{Poller, Submitter};
use rucio::daemons::reaper::Reaper;
use rucio::daemons::throttler::Throttler;
use rucio::daemons::{Ctx, Daemon};
use rucio::ftssim::FtsServer;
use rucio::mq::Broker;
use rucio::netsim::{Link, LinkFault, Network};
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::invariants;
use rucio::sim::scenario::{Event, Scenario};
use rucio::sim::workload::WorkloadSpec;
use rucio::storagesim::{synthetic_adler32_for, Fleet, StorageKind, StorageSystem};

/// Throttler-enabled deployment: SRC / MID / DST disk RSEs, fast links,
/// one FTS server.
fn rig() -> (Ctx, Arc<Catalog>) {
    let mut cfg = Config::new();
    cfg.set("throttler", "enabled", "true");
    cfg.set("throttler", "max_per_link", "2");
    cfg.set("conveyor", "retry_delay", "1m");
    let catalog = Arc::new(Catalog::new(Clock::sim_at(1_600_000_000_000), cfg));
    let now = catalog.now();
    catalog.add_scope("data18", "root").unwrap();
    let fleet = Arc::new(Fleet::new());
    let net = Arc::new(Network::new());
    for name in ["SRC", "MID", "DST"] {
        catalog
            .add_rse(Rse::new(name, now).with_attr("site", name).with_attr("type", "disk"))
            .unwrap();
        fleet.add(StorageSystem::new(name, StorageKind::Disk, u64::MAX));
    }
    for a in ["SRC", "MID", "DST"] {
        for b in ["SRC", "MID", "DST"] {
            if a != b {
                net.set_link(a, b, Link::new(100_000_000, 5, 1.0));
            }
        }
    }
    let broker = Broker::new();
    let fts = vec![Arc::new(FtsServer::new(
        "fts1",
        net.clone(),
        fleet.clone(),
        Some(broker.clone()),
    ))];
    let ctx = Ctx::new(catalog.clone(), fleet, net, fts, broker);
    (ctx, catalog)
}

/// Register a file; optionally put its bytes on the SRC endpoint.
fn seed_file(ctx: &Ctx, name: &str, bytes: u64, put: bool) -> DidKey {
    let cat = &ctx.catalog;
    let adler = synthetic_adler32_for(name, bytes);
    cat.add_file("data18", name, "root", bytes, &adler, None).unwrap();
    let key = DidKey::new("data18", name);
    let rep = cat.add_replica("SRC", &key, ReplicaState::Available, None).unwrap();
    if put {
        ctx.fleet.get("SRC").unwrap().put(&rep.pfn, bytes, cat.now()).unwrap();
    }
    key
}

fn advance(ctx: &Ctx, ms: i64) -> EpochMs {
    for fts in &ctx.fts {
        fts.advance(ctx.catalog.now());
    }
    if let Clock::Sim(s) = &ctx.catalog.clock {
        s.advance(ms);
    }
    let now = ctx.catalog.now();
    for fts in &ctx.fts {
        fts.advance(now);
    }
    now
}

fn assert_clean(cat: &Catalog) {
    assert_eq!(invariants::check(cat), Vec::new());
}

#[test]
fn full_lifecycle_waiting_queued_submitted_done() {
    let (ctx, cat) = rig();
    let f = seed_file(&ctx, "ok1", 1_000_000, true);
    let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST", 1)).unwrap();

    let req = cat.requests.scan(|_| true)[0].clone();
    assert_eq!(req.state, RequestState::Waiting, "admission state first");

    let mut throttler = Throttler::new(ctx.clone(), "t1");
    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");

    // the submitter must not see unadmitted work
    submitter.tick(cat.now());
    assert_eq!(cat.requests.get(&req.id).unwrap().state, RequestState::Waiting);

    // throttler admits, submitter submits
    assert_eq!(throttler.tick(cat.now()), 1);
    assert_eq!(cat.requests.get(&req.id).unwrap().state, RequestState::Queued);
    submitter.tick(cat.now());
    let sub = cat.requests.get(&req.id).unwrap();
    assert_eq!(sub.state, RequestState::Submitted);
    assert_eq!(sub.src_rse.as_deref(), Some("SRC"));
    assert!(sub.external_id.is_some());

    // bytes move, poller finishes the rule
    let now = advance(&ctx, 15_000);
    poller.tick(now);
    assert_eq!(cat.requests.get(&req.id).unwrap().state, RequestState::Done);
    assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok);
    assert_eq!(cat.get_replica("DST", &f).unwrap().state, ReplicaState::Available);
    assert_clean(&cat);
}

#[test]
fn failure_backs_off_then_retry_succeeds() {
    let (ctx, cat) = rig();
    // registered in the catalog but missing on storage → SOURCE error
    let f = seed_file(&ctx, "flaky", 1_000_000, false);
    let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST", 1)).unwrap();

    let mut throttler = Throttler::new(ctx.clone(), "t1");
    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");

    throttler.tick(cat.now());
    submitter.tick(cat.now());
    let now = advance(&ctx, 15_000);
    poller.tick(now);
    let req = cat.requests.scan(|_| true)[0].clone();
    assert_eq!(req.state, RequestState::Retry, "source error backs off");
    assert_eq!(req.attempts, 1);
    assert!(req.last_error.as_deref().unwrap_or("").contains("SOURCE"));
    assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Replicating);

    // the bytes appear; after the backoff the retry drives to DONE
    let src_pfn = cat.get_replica("SRC", &f).unwrap().pfn;
    ctx.fleet.get("SRC").unwrap().put(&src_pfn, 1_000_000, cat.now()).unwrap();
    let now = advance(&ctx, 61_000); // past retry_delay = 1m
    submitter.tick(now); // promotes due retries, then submits
    assert_eq!(cat.requests.get(&req.id).unwrap().state, RequestState::Submitted);
    let now = advance(&ctx, 15_000);
    poller.tick(now);
    assert_eq!(cat.requests.get(&req.id).unwrap().state, RequestState::Done);
    assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok);
    assert_clean(&cat);
}

#[test]
fn no_direct_link_multihop_chain_completes_and_is_reaped() {
    let (ctx, cat) = rig();
    let f = seed_file(&ctx, "far", 2_000_000, true);
    // the network between SRC and DST is partitioned; SRC→MID→DST lives
    ctx.net.set_fault_bidir("SRC", "DST", LinkFault::partition());
    let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST", 1)).unwrap();

    let mut throttler = Throttler::new(ctx.clone(), "t1");
    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");
    let mut reaper = Reaper::new(ctx.clone(), "r1");

    let mut hop_seen = false;
    for _ in 0..20 {
        let now = ctx.catalog.now();
        throttler.tick(now);
        submitter.tick(now);
        let now = advance(&ctx, 30_000);
        poller.tick(now);
        reaper.tick(now);
        if let Ok(rep) = cat.get_replica("MID", &f) {
            hop_seen = true;
            let req = cat.requests.scan(|_| true)[0].clone();
            assert_eq!(
                req.path,
                Some(vec!["SRC".into(), "MID".into(), "DST".into()]),
                "planned chain recorded on the request"
            );
            assert!(rep.lock_count == 0, "staging replicas are never rule-locked");
        }
        if cat.get_rule(rid).unwrap().state == RuleState::Ok
            && cat.get_replica("MID", &f).is_err()
        {
            break;
        }
    }
    assert!(hop_seen, "the chain staged through MID");
    assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok, "partitioned rule converges");
    assert_eq!(cat.get_replica("DST", &f).unwrap().state, ReplicaState::Available);
    // the intermediate replica was tombstoned on completion and reaped
    assert!(cat.get_replica("MID", &f).is_err(), "staging copy reaped");
    assert_eq!(ctx.fleet.get("MID").unwrap().file_count(), 0, "bytes gone too");
    assert_clean(&cat);
}

#[test]
fn throttler_caps_inflight_while_storm_drains() {
    let (ctx, cat) = rig();
    for i in 0..12 {
        let f = seed_file(&ctx, &format!("storm{i}"), 500_000, true);
        cat.add_rule(RuleSpec::new("root", f, "DST", 1)).unwrap();
    }
    let mut throttler = Throttler::new(ctx.clone(), "t1");
    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");
    for _ in 0..30 {
        let now = ctx.catalog.now();
        throttler.tick(now);
        // the admission cap (max_per_link = 2) bounds released work
        let released = cat.requests.count_where(|r| {
            matches!(r.state, RequestState::Queued | RequestState::Submitted)
        });
        assert!(released <= 2, "cap exceeded: {released}");
        submitter.tick(now);
        let now = advance(&ctx, 30_000);
        poller.tick(now);
        if cat.requests.count_where(|r| r.state == RequestState::Done) == 12 {
            break;
        }
    }
    assert_eq!(
        cat.requests.count_where(|r| r.state == RequestState::Done),
        12,
        "the whole storm drains through the cap"
    );
    assert_clean(&cat);
}

/// The acceptance scenario: a link-saturation storm on one destination
/// plus a DE↔FR partition, on the full simulated grid with the throttler
/// enabled. Throughout the run the invariant set (including the FTS
/// per-link cap check) holds; the low-share activity is not starved; and
/// the partitioned src→dst rule converges to OK via a multi-hop chain
/// whose staging replicas are eventually reaped.
#[test]
fn saturation_storm_with_partition_converges_under_caps() {
    const TICK: i64 = 10 * MINUTE_MS;
    let seed = 2042;
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "1h");
    cfg.set("heartbeat", "ttl", "45m");
    cfg.set("throttler", "enabled", "true");
    cfg.set("throttler", "max_per_link", "6");
    cfg.set("throttler", "share.Production", "4");
    cfg.set("throttler", "share.Analysis", "1");
    let mut d = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 3,
            files_per_dataset: 3,
            median_file_bytes: 200_000_000,
            derivations_per_day: 2,
            analysis_accesses_per_day: 20,
            seed: seed ^ 0xA0D,
            ..Default::default()
        },
        cfg,
    );
    d.enable_invariant_checks(2 * HOUR_MS);
    d.run_days(1, TICK); // warm steady state (datasets exist for the storm)

    let cat = d.ctx.catalog.clone();
    let now = cat.now();

    // A file whose only copy sits in DE, ruled onto FR while DE↔FR is
    // partitioned: only a multi-hop chain can satisfy it.
    let bytes = 80_000_000u64;
    let adler = synthetic_adler32_for("part.file", bytes);
    cat.add_file("data18", "part.file", "root", bytes, &adler, None).unwrap();
    let pf = DidKey::new("data18", "part.file");
    let rep = cat.add_replica("DE-T1-DISK", &pf, ReplicaState::Available, None).unwrap();
    d.ctx.fleet.get("DE-T1-DISK").unwrap().put(&rep.pfn, bytes, now).unwrap();
    cat.add_rule(RuleSpec::new("root", pf.clone(), "DE-T1-DISK", 1)).unwrap(); // pin source
    let far_rule = cat
        .add_rule(RuleSpec::new("root", pf.clone(), "FR-T1-DISK", 1).with_activity("Production"))
        .unwrap();

    // Low-share analysis pulls toward the destination the storm floods.
    let mut analysis_rules = Vec::new();
    for i in 0..4 {
        let name = format!("ana.file{i}");
        let bytes = 50_000_000u64;
        let adler = synthetic_adler32_for(&name, bytes);
        cat.add_file("data18", &name, "root", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        let rep = cat.add_replica("CERN-PROD", &key, ReplicaState::Available, None).unwrap();
        d.ctx.fleet.get("CERN-PROD").unwrap().put(&rep.pfn, bytes, now).unwrap();
        cat.add_rule(RuleSpec::new("root", key.clone(), "CERN-PROD", 1)).unwrap(); // pin
        analysis_rules.push(
            cat.add_rule(RuleSpec::new("root", key, "US-T2-1", 1).with_activity("Analysis"))
                .unwrap(),
        );
    }

    d.schedule_scenario(
        &Scenario::new("saturation storm + partition")
            .at(0, Event::NetworkPartition { region_a: "DE".into(), region_b: "FR".into() })
            .at(0, Event::LinkSaturationStorm {
                rse_expression: "US-T2-1".into(),
                datasets: 20,
                activity: "Production".into(),
            }),
    );
    d.run_days(2, TICK);

    // 1. every invariant — including the FTS per-link cap — held at every
    //    check point of the run
    assert!(
        d.violations.is_empty(),
        "invariants violated: {:?}",
        d.violations.iter().take(5).collect::<Vec<_>>()
    );
    assert!(cat.metrics.counter("scenario.saturation_rules") > 0, "storm fired");
    assert!(cat.metrics.counter("throttler.released") > 0, "admission control ran");

    // 2. the low-share activity was not starved: all its rules are OK
    for rid in &analysis_rules {
        assert_eq!(
            cat.get_rule(*rid).unwrap().state,
            RuleState::Ok,
            "low-share Analysis rule {rid} starved"
        );
    }

    // 3. the partitioned pair converged via a multi-hop chain...
    assert!(cat.metrics.counter("conveyor.multihop.planned") > 0, "chain planned");
    assert_eq!(
        cat.get_rule(far_rule).unwrap().state,
        RuleState::Ok,
        "partitioned DE→FR rule converges via multi-hop"
    );
    assert_eq!(cat.get_replica("FR-T1-DISK", &pf).unwrap().state, ReplicaState::Available);
    // ...and its staging replicas are gone again: only the pinned source
    // and the ruled destination remain
    let mut where_now: Vec<String> =
        cat.list_replicas(&pf).into_iter().map(|r| r.rse).collect();
    where_now.sort();
    assert_eq!(
        where_now,
        vec!["DE-T1-DISK".to_string(), "FR-T1-DISK".to_string()],
        "intermediate replicas eventually reaped"
    );
}
